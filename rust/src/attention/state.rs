//! Per-sequence KV state for the stateful prefill/decode attention API —
//! now **paged**: residency is allocated in fixed-size pages (vLLM-style
//! block tables), not one contiguous growing buffer per (layer, head, side).
//!
//! ## Why the paper's dataflow wants resident operands
//!
//! The paper's whole point is an unbroken integer dataflow; a serving path
//! that stores FP32 K/V history and re-quantizes it on every decode step
//! breaks that dataflow and costs O(L·d) redundant conversions per token.
//! Instead, each pipeline owns a [`KvState`] per sequence (per head) holding
//! K/V **in the pipeline's native operand format**:
//!
//! * integer pipelines (Quant-Only, IntAttention, EXAQ) keep K̂/V̂ as INT8
//!   rows plus one running per-tensor scale each ([`Int8KvState`]). A decode
//!   step quantizes only the new row. When a new row's magnitude exceeds the
//!   running abs-max, the resident rows are re-mapped to the wider grid in
//!   the integer domain (`round(x̂·s_old/s_new)`) — an O(L·d) event that
//!   occurs only when the running maximum actually grows, not per token
//!   (the same "keep quantized operands resident" discipline as I-BERT and
//!   the ITA accelerator).
//! * FP32 / FP16 pipelines keep native-dtype rows ([`F32KvState`],
//!   [`F16KvState`]).
//!
//! ## Paged residency ([`PagedRows`])
//!
//! Each side (K or V) stores its rows in a [`PagedRows`] — an ordered list
//! of fixed-size pages of [`kv_page_rows`] rows each (`INTATTN_KV_PAGE`
//! override, default 64; snapshotted once per process), plus a row count.
//! Rows never span pages, so every page is a contiguous `rows×d` row-major
//! segment the GEMM kernels consume directly (`crate::gemm`'s `*_paged`
//! kernels and the grouped decode descriptors walk the page list — there is
//! no "copy into one contiguous buffer" escape hatch anywhere on the decode
//! path). This fixes three contiguous-layout costs at once:
//!
//! * **append** fills the tail page in place and takes a fresh page from
//!   the pool when it is full — no `Vec`-doubling reallocation ever copies
//!   the resident history again (the decode-throughput bench reports the
//!   copy traffic the old layout paid);
//! * **re-scale** re-maps page by page, in place;
//! * **memory accounting is exact**: [`KvState::bytes`] is pages × page
//!   bytes — allocated capacity, not a `len`-derived estimate that ignored
//!   up to 2× of `Vec` growth slack — and the coordinator budgets whole
//!   pages ([`crate::coordinator::batcher::BatchPolicy::max_kv_pages`]).
//!
//! Pages come from a **process-wide [`PagePool`]** (one per element type):
//! a free-list of recycled page boxes, so a finished sequence's pages return
//! to the pool the round it completes and the next admission reuses them
//! instead of hitting the allocator. [`page_pool_stats`] exposes the
//! allocated/recycled/released/CoW counters the serving metrics and benches
//! report.
//!
//! ## Copy-on-write page sharing (prefix sharing across requests)
//!
//! Pages are **refcounted** (`Arc`), so two stores can reference the same
//! physical page: [`PagedRows::share_prefix`] builds a new store whose first
//! `rows` rows alias the donor's pages without copying them — the mechanism
//! behind request-level prefix sharing (N requests with the same system
//! prompt hold one set of prefix pages plus per-request suffixes; see
//! `crate::coordinator::prefix`). The ownership rules are:
//!
//! * **A shared page is immutable.** Every read path (`row`,
//!   [`PagedRows::page_slices`] / [`PagedRows::page_list`] — and therefore
//!   every GEMM descriptor the pipelines build) works on `&[T]` and never
//!   cares whether the page is exclusively owned.
//! * **Every mutation forks first.** The only two mutation paths —
//!   [`PagedRows::append_row`] (which touches the tail page) and
//!   [`PagedRows::for_each_mut`] (the INT8 re-scale remap, which touches
//!   every page) — check the refcount and, if the page is shared, copy it
//!   into a fresh pool page before writing (`cow_forks` counts these).
//!   Sharers therefore **never observe each other's rewrites**: a donor
//!   whose running abs-max grows re-maps private copies, and the adopters
//!   keep the original bytes.
//! * **Scales pin with the share.** The integer states' scale/abs-max/Δ-stat
//!   bookkeeping is *copied* (not aliased) at share time, so a shared page
//!   run is always paired with the scale that produced it. Callers who need
//!   byte-identity with unshared execution must share at a moment when the
//!   donor's running scale covers exactly the shared rows — i.e.
//!   `rows == len()`, which the coordinator guarantees by snapshotting only
//!   at aligned prefill-chunk boundaries.
//! * **The last holder releases.** Dropping a store releases only the pages
//!   whose refcount hits zero back to the pool (`released` counts returns),
//!   so `allocated + recycled − released` is the exact number of
//!   outstanding pages — what the leak property test in `tests/kv_paging.rs`
//!   drives back to baseline.
//!
//! Layout changes nothing numerically: rows hold exactly the values the
//! contiguous layout held, and every kernel computes the same per-row dot
//! products in the same order, so paged attention output is **byte-equal**
//! to the contiguous implementation at any page size (asserted for all six
//! pipeline kinds in `tests/decode_equivalence.rs` and the property test in
//! `tests/kv_paging.rs`); and because every mutation forks shared pages
//! first, shared-prefix execution is byte-equal to unshared execution under
//! the same chunk schedule (asserted there too).
//!
//! States also carry the running Δ-statistics EXAQ's dynamic clipping needs
//! ([`ExaqRunningStats`]), so EXAQ decode keeps its O(1)-per-token cost
//! instead of re-scanning history for the clip range.

use crate::attention::PipelineKind;
use crate::tensor::MatF32;
use crate::util::f16::F16;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// Page size policy

/// Default rows per KV page (vLLM's common block size; 64 rows × d=128 INT8
/// elements is an 8 KiB page).
pub const DEFAULT_KV_PAGE_ROWS: usize = 64;

/// Rows per KV page: `INTATTN_KV_PAGE` override, else
/// [`DEFAULT_KV_PAGE_ROWS`]. Snapshotted **once** per process (with the
/// other knobs, [`crate::util::env::knobs`]) so every state in a process
/// agrees on the page geometry; tests that need specific page sizes use
/// [`KvState::with_page_rows`] / [`PagedRows::with_page_rows`] instead of
/// mutating the environment (parse policy:
/// [`crate::util::env::page_rows_from`]).
pub fn kv_page_rows() -> usize {
    crate::util::env::knobs().kv_page_rows
}

// ---------------------------------------------------------------------------
// PagePool — process-wide free-list of recycled page boxes

/// Total elements the free list may hold per element type before released
/// pages go back to the allocator instead (bounds the pool's idle footprint
/// at 16 Mi elements — 16 MiB for INT8 pages, 64 MiB for f32).
const MAX_FREE_ELEMS: usize = 1 << 24;

struct FreeList<T> {
    /// Free pages bucketed by exact capacity: `(capacity, pages)`. A
    /// process sees only a handful of distinct page geometries (one per
    /// (head_dim, page-rows) pair in use), so the bucket scan is O(few)
    /// and pop/push within a bucket is O(1) — the free list can hold
    /// hundreds of thousands of pages without the decode-path `acquire`
    /// ever scanning them.
    buckets: Vec<(usize, Vec<Box<[T]>>)>,
    elems: usize,
}

/// Process-wide recycling pool for KV pages of one element type. A
/// [`PagedRows`] acquires pages here on growth and releases them on drop,
/// so a finished sequence's pages are reused by the next admission instead
/// of cycling through the allocator. Pages of different capacities (page
/// geometry varies with head_dim and page-rows overrides) live in separate
/// buckets; `acquire` matches on exact capacity.
pub struct PagePool<T> {
    free: Mutex<FreeList<T>>,
    /// Pages created fresh from the allocator.
    allocated: AtomicU64,
    /// Pages handed out from the free list instead of the allocator.
    recycled: AtomicU64,
    /// Pages returned by stores (whether pooled or dropped over the cap).
    /// `allocated + recycled − released` = pages currently held by stores.
    released: AtomicU64,
    /// Copy-on-write forks: times a store copied a shared page before
    /// mutating it (tail-append divergence or a re-scale remap unsharing).
    cow_forks: AtomicU64,
}

impl<T: Copy + Default> PagePool<T> {
    fn new() -> Self {
        PagePool {
            free: Mutex::new(FreeList { buckets: Vec::new(), elems: 0 }),
            allocated: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
            released: AtomicU64::new(0),
            cow_forks: AtomicU64::new(0),
        }
    }

    fn acquire(&self, cap: usize) -> Box<[T]> {
        // Fault-injection point (inert unless a plan is armed): fires before
        // any counter moves, so a simulated allocation failure never skews
        // `allocated + recycled − released`.
        crate::util::fault::on_pool_alloc();
        {
            let mut f = self.free.lock().unwrap();
            if let Some(page) = f
                .buckets
                .iter_mut()
                .find(|(c, _)| *c == cap)
                .and_then(|(_, pages)| pages.pop())
            {
                f.elems -= cap;
                self.recycled.fetch_add(1, Ordering::Relaxed);
                return page;
            }
        }
        self.allocated.fetch_add(1, Ordering::Relaxed);
        vec![T::default(); cap].into_boxed_slice()
    }

    fn release(&self, page: Box<[T]>) {
        self.released.fetch_add(1, Ordering::Relaxed);
        let cap = page.len();
        let mut f = self.free.lock().unwrap();
        if f.elems + cap > MAX_FREE_ELEMS {
            // Over the cap: the page drops back to the allocator.
            return;
        }
        f.elems += cap;
        if let Some((_, pages)) = f.buckets.iter_mut().find(|(c, _)| *c == cap) {
            pages.push(page);
        } else {
            f.buckets.push((cap, vec![page]));
        }
    }

    fn note_cow(&self) {
        self.cow_forks.fetch_add(1, Ordering::Relaxed);
    }

    /// Monotone counters since process start.
    pub fn stats(&self) -> PagePoolStats {
        PagePoolStats {
            allocated: self.allocated.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            released: self.released.load(Ordering::Relaxed),
            cow_forks: self.cow_forks.load(Ordering::Relaxed),
        }
    }
}

/// Monotone page-pool counters (one [`PagePool`] per element type;
/// [`page_pool_stats`] sums across them).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PagePoolStats {
    /// Pages created fresh from the allocator.
    pub allocated: u64,
    /// Pages handed out from the free list instead of the allocator.
    pub recycled: u64,
    /// Pages returned by stores (pooled or dropped over the free-list cap).
    pub released: u64,
    /// Copy-on-write forks of shared pages.
    pub cow_forks: u64,
}

impl PagePoolStats {
    /// Pages currently held by live stores: every handout
    /// (`allocated + recycled`) minus every return (`released`). A schedule
    /// that builds and then drops an arbitrary web of shared states must
    /// bring this back to its starting value — the refcount-leak invariant.
    pub fn outstanding(&self) -> u64 {
        self.allocated + self.recycled - self.released
    }

    fn add(&mut self, o: PagePoolStats) {
        self.allocated += o.allocated;
        self.recycled += o.recycled;
        self.released += o.released;
        self.cow_forks += o.cow_forks;
    }
}

/// Element types that have a process-wide [`PagePool`].
pub trait PageElem: Copy + Default + Send + Sync + 'static {
    fn pool() -> &'static PagePool<Self>;
}

macro_rules! impl_page_elem {
    ($t:ty) => {
        impl PageElem for $t {
            fn pool() -> &'static PagePool<Self> {
                static POOL: OnceLock<PagePool<$t>> = OnceLock::new();
                POOL.get_or_init(PagePool::new)
            }
        }
    };
}

impl_page_elem!(i8);
impl_page_elem!(f32);
impl_page_elem!(F16);

/// Aggregate page-pool counters across every element type's pool — what the
/// serving metrics and the decode bench report.
pub fn page_pool_stats() -> PagePoolStats {
    let mut s = <i8 as PageElem>::pool().stats();
    s.add(<f32 as PageElem>::pool().stats());
    s.add(<F16 as PageElem>::pool().stats());
    s
}

// ---------------------------------------------------------------------------
// PagedRows — the block-table row store

/// Append-only row store backed by fixed-size pages: an ordered page list
/// plus a row count. Every page holds whole `d`-element rows (rows never
/// span pages), so each page is a contiguous row-major segment the GEMM
/// kernels consume directly via [`PagedRows::page_list`]. Pages are
/// acquired from the process-wide [`PagePool`] on growth and released back
/// when the last reference drops.
///
/// Pages are **refcounted**: [`PagedRows::share_prefix`] (and `Clone`)
/// alias pages between stores instead of copying them, and both mutation
/// paths ([`PagedRows::append_row`], [`PagedRows::for_each_mut`]) fork a
/// shared page copy-on-write before writing — see the module docs for the
/// ownership rules.
pub struct PagedRows<T: PageElem> {
    pages: Vec<Arc<Box<[T]>>>,
    /// Rows appended so far.
    len: usize,
    /// Elements per row.
    d: usize,
    /// Rows per page.
    page_rows: usize,
}

impl<T: PageElem> PagedRows<T> {
    /// Store with the process-wide page size ([`kv_page_rows`]).
    pub fn new(d: usize) -> Self {
        Self::with_page_rows(d, kv_page_rows())
    }

    /// Store with an explicit page size (tests sweep 1/2/64 and a
    /// one-big-page "contiguous" oracle in a single process).
    pub fn with_page_rows(d: usize, page_rows: usize) -> Self {
        assert!(d > 0, "row width must be positive");
        assert!(page_rows > 0, "page size must be positive");
        PagedRows { pages: Vec::new(), len: 0, d, page_rows }
    }

    /// Rows stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Elements per row.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Rows per page.
    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Valid elements stored (`len × d`).
    pub fn elems(&self) -> usize {
        self.len * self.d
    }

    /// Pages allocated.
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    /// Allocated capacity in bytes — pages × page bytes, exactly what this
    /// store holds from the allocator/pool (no hidden growth slack).
    pub fn bytes_allocated(&self) -> usize {
        self.pages.len() * self.page_cap() * std::mem::size_of::<T>()
    }

    /// Elements per page.
    fn page_cap(&self) -> usize {
        self.page_rows * self.d
    }

    /// Append one row and return its slice for the caller to fill — the
    /// only growth path. Fills the tail page in place; takes a page from
    /// the pool exactly when capacity is exhausted. Never copies resident
    /// rows, with one exception: if the tail page is shared (a prefix
    /// adoption ended mid-page), the **first divergent append forks it**
    /// copy-on-write so the other sharers never see the new row.
    pub fn append_row(&mut self) -> &mut [T] {
        if self.len == self.pages.len() * self.page_rows {
            self.pages.push(Arc::new(T::pool().acquire(self.page_cap())));
        }
        let off = (self.len % self.page_rows) * self.d;
        let end = off + self.d;
        self.len += 1;
        let tail = self.pages.len() - 1;
        &mut self.page_mut(tail)[off..end]
    }

    /// Mutable access to page `i`, forking it copy-on-write first if any
    /// other store holds a reference. After this call the page is
    /// exclusively owned.
    fn page_mut(&mut self, i: usize) -> &mut [T] {
        if Arc::get_mut(&mut self.pages[i]).is_none() {
            let mut fresh = T::pool().acquire(self.page_cap());
            fresh.copy_from_slice(&self.pages[i]);
            // Swap our reference out and route it through `into_inner`: if
            // the other holder dropped concurrently between our refcount
            // check and here, we may now BE the last reference, and a plain
            // Arc drop would free the page behind the pool's back. The
            // remaining sharers (if any) keep the original bytes.
            let old = std::mem::replace(&mut self.pages[i], Arc::new(fresh));
            if let Some(page) = Arc::into_inner(old) {
                T::pool().release(page);
            }
            T::pool().note_cow();
        }
        Arc::get_mut(&mut self.pages[i]).expect("page just unshared")
    }

    /// Pages currently shared with at least one other store (refcount > 1).
    pub fn shared_pages(&self) -> usize {
        self.pages.iter().filter(|p| Arc::strong_count(p) > 1).count()
    }

    /// A new store whose first `rows` rows alias this store's pages
    /// (refcounted, no copy) — the copy-on-write prefix-sharing entry
    /// point. If `rows` ends mid-page the tail page is shared too; the
    /// first divergent append on either side forks it. For integer states
    /// the caller must pair the shared run with the scale that produced it
    /// (see [`KvState::share_prefix`]).
    pub fn share_prefix(&self, rows: usize) -> PagedRows<T> {
        assert!(rows <= self.len, "cannot share {rows} of {} rows", self.len);
        let pages_needed = rows.div_ceil(self.page_rows);
        PagedRows {
            pages: self.pages[..pages_needed].to_vec(),
            len: rows,
            d: self.d,
            page_rows: self.page_rows,
        }
    }

    /// Row `r` (always contiguous: rows never span pages).
    pub fn row(&self, r: usize) -> &[T] {
        assert!(r < self.len, "row {r} out of {} stored", self.len);
        let off = (r % self.page_rows) * self.d;
        &self.pages[r / self.page_rows][off..off + self.d]
    }

    /// The valid row-major segment of each page, in order (tail trimmed to
    /// the rows actually stored). This is the block table the paged GEMM
    /// kernels walk.
    pub fn page_slices(&self) -> impl Iterator<Item = &[T]> {
        let (pr, d, len) = (self.page_rows, self.d, self.len);
        self.pages.iter().enumerate().filter_map(move |(i, p)| {
            let start = i * pr;
            if start >= len {
                return None;
            }
            Some(&p[..(len - start).min(pr) * d])
        })
    }

    /// [`Self::page_slices`], collected — the per-call descriptor the
    /// kernels take (O(pages) pointers, not a data copy). The collect is a
    /// small per-call allocation, in the same class as the logit/output
    /// buffers every attention call already allocates; if it ever shows up
    /// in profiles, a descriptor cached on the store and refreshed on page
    /// growth is the next step.
    pub fn page_list(&self) -> Vec<&[T]> {
        self.page_slices().collect()
    }

    /// Valid elements in storage order.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.page_slices().flat_map(|p| p.iter())
    }

    /// Mutate every valid element in place, page by page (the INT8
    /// re-scale remap). Shared pages are **unshared first** (forked
    /// copy-on-write), so a re-scale rewrites private copies and the other
    /// holders of a shared prefix keep the bytes their own scale describes.
    pub fn for_each_mut(&mut self, mut f: impl FnMut(&mut T)) {
        let (pr, d, len) = (self.page_rows, self.d, self.len);
        for i in 0..self.pages.len() {
            let start = i * pr;
            if start >= len {
                break;
            }
            let valid = (len - start).min(pr) * d;
            for x in &mut self.page_mut(i)[..valid] {
                f(x);
            }
        }
    }
}

impl<T: PageElem> Drop for PagedRows<T> {
    fn drop(&mut self) {
        for p in self.pages.drain(..) {
            // Only the last holder returns the page to the pool; earlier
            // drops just lower the refcount. `into_inner` (not `try_unwrap`)
            // so two holders dropping concurrently on different threads
            // cannot both observe count > 1 and leak the page — exactly one
            // caller wins the unwrap.
            if let Some(page) = Arc::into_inner(p) {
                T::pool().release(page);
            }
        }
    }
}

impl<T: PageElem> Clone for PagedRows<T> {
    /// Clones **share** pages (refcount bump, no copy): with every mutation
    /// path forking shared pages first, an aliased clone is observationally
    /// identical to a deep copy — the copies happen lazily, only for pages
    /// a side actually rewrites.
    fn clone(&self) -> Self {
        PagedRows {
            pages: self.pages.clone(),
            len: self.len,
            d: self.d,
            page_rows: self.page_rows,
        }
    }
}

impl<T: PageElem> std::fmt::Debug for PagedRows<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedRows")
            .field("rows", &self.len)
            .field("d", &self.d)
            .field("page_rows", &self.page_rows)
            .field("pages", &self.pages.len())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// KV states

/// One side (K or V) of an INT8-resident state: quantized rows in pages,
/// plus the running per-tensor scale bookkeeping.
#[derive(Clone, Debug)]
pub struct Int8Side {
    /// Quantized rows, `len×d` row-major across the page list.
    pub data: PagedRows<i8>,
    /// Dequantization scale: `x ≈ scale · x̂` (1.0 while all-zero).
    pub scale: f32,
    /// Running abs-max over every row ever appended.
    pub amax: f32,
    /// How many times the resident rows were re-mapped to a wider grid.
    pub rescales: u64,
}

impl Int8Side {
    fn with_page_rows(d: usize, page_rows: usize) -> Self {
        Int8Side {
            data: PagedRows::with_page_rows(d, page_rows),
            scale: 1.0,
            amax: 0.0,
            rescales: 0,
        }
    }

    /// Share the first `rows` quantized rows (refcounted pages, no copy)
    /// and **pin the current scale to the share**: the new side carries
    /// this side's scale/abs-max bookkeeping, so the shared bytes stay
    /// paired with the grid that produced them. Byte-identity with
    /// unshared execution additionally requires `rows == len()` at share
    /// time (the running scale then covers exactly the shared rows).
    fn share_prefix(&self, rows: usize) -> Int8Side {
        Int8Side {
            data: self.data.share_prefix(rows),
            scale: self.scale,
            amax: self.amax,
            rescales: self.rescales,
        }
    }

    /// Quantize and append `rows`, widening the grid first if the running
    /// abs-max grew. Matches `quantize_i8`'s conventions (symmetric ±127,
    /// scale 1.0 for all-zero data), so after any append sequence the scale
    /// equals what one-shot quantization of the concatenated rows would use.
    ///
    /// Returns the number of resident elements re-mapped by the re-scale
    /// path (0 on the common fast path) so callers can charge the work to
    /// their op counters.
    fn append(&mut self, rows: &MatF32) -> usize {
        let mut remapped = 0;
        let new_amax = rows.abs_max();
        if new_amax > self.amax {
            let new_scale = new_amax / 127.0;
            if !self.data.is_empty() && self.amax > 0.0 {
                // Re-scale path: re-map resident INT8 rows onto the wider
                // grid entirely in the quantized domain (no FP32 history
                // exists to re-quantize from — that is the point), one page
                // at a time. Exclusively-owned pages remap in place; pages
                // shared with a prefix sharer are forked first
                // (`for_each_mut`'s copy-on-write), so the sharers keep the
                // bytes their own pinned scale describes.
                let ratio = self.scale / new_scale;
                self.data.for_each_mut(|q| {
                    *q = ((*q as f32) * ratio).round().clamp(-127.0, 127.0) as i8;
                });
                self.rescales += 1;
                remapped = self.data.elems();
            }
            self.amax = new_amax;
            self.scale = new_scale;
        }
        let inv = 1.0 / self.scale;
        for r in 0..rows.rows() {
            let dst = self.data.append_row();
            for (o, &x) in dst.iter_mut().zip(rows.row(r)) {
                *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
        remapped
    }
}

/// Running statistics of the max-subtracted distances `Δ = m − a` (scaled by
/// α), accumulated across prefill/decode calls — EXAQ's dynamic clip range
/// without the per-step O(L) history re-scan.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExaqRunningStats {
    pub sum: f64,
    pub sumsq: f64,
    pub n: u64,
}

impl ExaqRunningStats {
    pub fn merge(&mut self, sum: f64, sumsq: f64, n: u64) {
        self.sum += sum;
        self.sumsq += sumsq;
        self.n += n;
    }

    /// Standard deviation of all Δ seen so far (0 before any data).
    pub fn sigma(&self) -> f32 {
        if self.n == 0 {
            return 0.0;
        }
        let mean = self.sum / self.n as f64;
        let var = (self.sumsq / self.n as f64 - mean * mean).max(0.0);
        var.sqrt() as f32
    }
}

/// INT8-resident K/V state (Quant-Only, IntAttention, EXAQ pipelines).
/// The cached length is **derived** from the page store (`len()`), never
/// mirrored — there is exactly one source of truth for how many rows are
/// resident.
#[derive(Clone, Debug)]
pub struct Int8KvState {
    pub d: usize,
    pub k: Int8Side,
    pub v: Int8Side,
    /// Used only by the EXAQ pipelines (zero-cost for the others).
    pub exaq: ExaqRunningStats,
}

impl Int8KvState {
    /// Cached positions (rows per side; K and V always advance together).
    pub fn len(&self) -> usize {
        self.k.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// FP32-resident K/V state. Length is derived from the page store.
#[derive(Clone, Debug)]
pub struct F32KvState {
    pub d: usize,
    /// `len×d` row-major keys across the page list.
    pub k: PagedRows<f32>,
    /// `len×d` row-major values across the page list.
    pub v: PagedRows<f32>,
}

impl F32KvState {
    /// Cached positions (rows per side).
    pub fn len(&self) -> usize {
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// FP16-storage K/V state (binary16 rows, decoded tile-wise at compute
/// time). Length is derived from the page store.
#[derive(Clone, Debug)]
pub struct F16KvState {
    pub d: usize,
    pub k: PagedRows<F16>,
    pub v: PagedRows<F16>,
}

impl F16KvState {
    /// Cached positions (rows per side).
    pub fn len(&self) -> usize {
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A per-sequence (per-head) KV cache entry owned by the pipeline kind that
/// created it. Appending K/V rows converts them **once** into the pipeline's
/// operand format; no later call re-quantizes or re-copies history.
#[derive(Clone, Debug)]
pub enum KvState {
    F32(F32KvState),
    F16(F16KvState),
    Int8(Int8KvState),
}

impl KvState {
    /// The state format a pipeline kind keeps resident, paged at the
    /// process-wide page size ([`kv_page_rows`]).
    pub fn new(kind: PipelineKind, head_dim: usize) -> KvState {
        Self::with_page_rows(kind, head_dim, kv_page_rows())
    }

    /// [`Self::new`] with an explicit page size (tests compare page sizes
    /// 1/2/64 against a one-big-page contiguous oracle in one process).
    pub fn with_page_rows(kind: PipelineKind, head_dim: usize, page_rows: usize) -> KvState {
        assert!(head_dim > 0, "head_dim must be positive");
        match kind {
            PipelineKind::Fp32 => KvState::F32(F32KvState {
                d: head_dim,
                k: PagedRows::with_page_rows(head_dim, page_rows),
                v: PagedRows::with_page_rows(head_dim, page_rows),
            }),
            PipelineKind::Fp16 => KvState::F16(F16KvState {
                d: head_dim,
                k: PagedRows::with_page_rows(head_dim, page_rows),
                v: PagedRows::with_page_rows(head_dim, page_rows),
            }),
            PipelineKind::QuantOnly
            | PipelineKind::IntAttention
            | PipelineKind::ExaqInt2
            | PipelineKind::ExaqInt3 => KvState::Int8(Int8KvState {
                d: head_dim,
                k: Int8Side::with_page_rows(head_dim, page_rows),
                v: Int8Side::with_page_rows(head_dim, page_rows),
                exaq: ExaqRunningStats::default(),
            }),
        }
    }

    /// Cached positions (derived from the page stores — no mirror field to
    /// drift out of sync).
    pub fn len(&self) -> usize {
        match self {
            KvState::F32(s) => s.len(),
            KvState::F16(s) => s.len(),
            KvState::Int8(s) => s.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Head dimension the state was built for.
    pub fn head_dim(&self) -> usize {
        match self {
            KvState::F32(s) => s.d,
            KvState::F16(s) => s.d,
            KvState::Int8(s) => s.d,
        }
    }

    /// Append `k_rows`/`v_rows` (equal row counts, `head_dim` columns) in
    /// the state's native format. Returns the number of resident elements
    /// the INT8 re-scale path re-mapped (0 for float states and on the
    /// common integer fast path).
    pub fn append(&mut self, k_rows: &MatF32, v_rows: &MatF32) -> usize {
        let n = k_rows.rows();
        assert_eq!(v_rows.rows(), n, "K/V row count mismatch");
        assert_eq!(k_rows.cols(), self.head_dim(), "K head_dim");
        assert_eq!(v_rows.cols(), self.head_dim(), "V head_dim");
        match self {
            KvState::F32(s) => {
                for r in 0..n {
                    s.k.append_row().copy_from_slice(k_rows.row(r));
                    s.v.append_row().copy_from_slice(v_rows.row(r));
                }
                0
            }
            KvState::F16(s) => {
                for r in 0..n {
                    for (o, &x) in s.k.append_row().iter_mut().zip(k_rows.row(r)) {
                        *o = F16::from_f32(x);
                    }
                    for (o, &x) in s.v.append_row().iter_mut().zip(v_rows.row(r)) {
                        *o = F16::from_f32(x);
                    }
                }
                0
            }
            KvState::Int8(s) => s.k.append(k_rows) + s.v.append(v_rows),
        }
    }

    /// Actual memory footprint in bytes: **allocated page capacity** (pages
    /// × page bytes) at the native element width, plus the scale/statistics
    /// bookkeeping integer states carry. Exact by construction — the old
    /// contiguous layout reported `len`-derived payload and ignored up to
    /// 2× of `Vec` growth slack, so peak RSS could exceed the admission
    /// budget it was checked against.
    pub fn bytes(&self) -> usize {
        match self {
            KvState::F32(s) => s.k.bytes_allocated() + s.v.bytes_allocated(),
            KvState::F16(s) => s.k.bytes_allocated() + s.v.bytes_allocated(),
            // INT8 pages + per-side (scale, amax, rescales) + EXAQ stats.
            KvState::Int8(s) => {
                s.k.data.bytes_allocated() + s.v.data.bytes_allocated() + 2 * 16 + 24
            }
        }
    }

    /// Pages allocated across both sides — what the coordinator's
    /// page-budget admission charges and frees.
    pub fn pages(&self) -> usize {
        match self {
            KvState::F32(s) => s.k.pages() + s.v.pages(),
            KvState::F16(s) => s.k.pages() + s.v.pages(),
            KvState::Int8(s) => s.k.data.pages() + s.v.data.pages(),
        }
    }

    /// Row slots the allocated pages could hold (both sides) — the
    /// denominator of tail-page utilization.
    pub fn capacity_rows(&self) -> usize {
        let side = |p: usize, pr: usize| p * pr;
        match self {
            KvState::F32(s) => side(s.k.pages(), s.k.page_rows()) + side(s.v.pages(), s.v.page_rows()),
            KvState::F16(s) => side(s.k.pages(), s.k.page_rows()) + side(s.v.pages(), s.v.page_rows()),
            KvState::Int8(s) => {
                side(s.k.data.pages(), s.k.data.page_rows())
                    + side(s.v.data.pages(), s.v.data.page_rows())
            }
        }
    }

    /// Rows stored across both sides (`2 × len`).
    pub fn rows_stored(&self) -> usize {
        2 * self.len()
    }

    /// Pages (both sides) currently shared with another state (refcount
    /// > 1) — zero once every sharer has forked or dropped.
    pub fn shared_pages(&self) -> usize {
        match self {
            KvState::F32(s) => s.k.shared_pages() + s.v.shared_pages(),
            KvState::F16(s) => s.k.shared_pages() + s.v.shared_pages(),
            KvState::Int8(s) => s.k.data.shared_pages() + s.v.data.shared_pages(),
        }
    }

    /// A state whose first `rows` positions alias this state's pages
    /// copy-on-write ([`PagedRows::share_prefix`]) — the adoption step of
    /// request-level prefix sharing. The integer states' running
    /// scale/abs-max (and EXAQ Δ-stats) are **copied** alongside the page
    /// refs, pinning the shared run to the grid that produced it; for the
    /// result to be byte-identical to the adopter having computed the
    /// prefix itself, share at a moment when `rows == len()` (the
    /// coordinator snapshots only at aligned prefill-chunk boundaries for
    /// exactly this reason). Neither state can observe the other's later
    /// mutations: appends and re-scale remaps fork shared pages first.
    pub fn share_prefix(&self, rows: usize) -> KvState {
        assert!(rows <= self.len(), "cannot share {rows} of {} cached rows", self.len());
        match self {
            KvState::F32(s) => KvState::F32(F32KvState {
                d: s.d,
                k: s.k.share_prefix(rows),
                v: s.v.share_prefix(rows),
            }),
            KvState::F16(s) => KvState::F16(F16KvState {
                d: s.d,
                k: s.k.share_prefix(rows),
                v: s.v.share_prefix(rows),
            }),
            KvState::Int8(s) => KvState::Int8(Int8KvState {
                d: s.d,
                k: s.k.share_prefix(rows),
                v: s.v.share_prefix(rows),
                exaq: s.exaq,
            }),
        }
    }

    /// The INT8 state, panicking if this state was built by a float pipeline.
    pub fn as_int8(&self) -> &Int8KvState {
        match self {
            KvState::Int8(s) => s,
            other => panic!(
                "pipeline expects an INT8 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_int8_mut(&mut self) -> &mut Int8KvState {
        match self {
            KvState::Int8(s) => s,
            other => panic!(
                "pipeline expects an INT8 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f32(&self) -> &F32KvState {
        match self {
            KvState::F32(s) => s,
            other => panic!(
                "pipeline expects an FP32 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut F32KvState {
        match self {
            KvState::F32(s) => s,
            other => panic!(
                "pipeline expects an FP32 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f16(&self) -> &F16KvState {
        match self {
            KvState::F16(s) => s,
            other => panic!(
                "pipeline expects an FP16 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    pub fn as_f16_mut(&mut self) -> &mut F16KvState {
        match self {
            KvState::F16(s) => s,
            other => panic!(
                "pipeline expects an FP16 KV state, got {} (state built by a different pipeline kind)",
                other.storage_name()
            ),
        }
    }

    /// Storage format name (diagnostics).
    pub fn storage_name(&self) -> &'static str {
        match self {
            KvState::F32(_) => "fp32",
            KvState::F16(_) => "fp16",
            KvState::Int8(_) => "int8",
        }
    }
}

/// Bytes one cached token costs for `kind` at head dimension `d` across K
/// and V (payload only — page rounding and the per-state constant overhead
/// are excluded so the estimate scales linearly; page-granular admission
/// uses [`crate::model::lm::KvCache::pages_for_tokens`] instead).
pub fn kv_bytes_per_token(kind: PipelineKind, d: usize) -> usize {
    let elem = match kind {
        PipelineKind::Fp32 => 4,
        PipelineKind::Fp16 => 2,
        _ => 1,
    };
    2 * d * elem
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_i8;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn kinds_map_to_expected_storage() {
        assert_eq!(KvState::new(PipelineKind::Fp32, 8).storage_name(), "fp32");
        assert_eq!(KvState::new(PipelineKind::Fp16, 8).storage_name(), "fp16");
        for kind in [
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
            PipelineKind::ExaqInt2,
            PipelineKind::ExaqInt3,
        ] {
            assert_eq!(KvState::new(kind, 8).storage_name(), "int8");
        }
    }

    #[test]
    fn page_rows_policy() {
        // The parse policy lives (and is exercised) in `crate::util::env`;
        // this checks only the snapshot wiring.
        assert!(kv_page_rows() >= 1);
        assert_eq!(kv_page_rows(), crate::util::env::knobs().kv_page_rows);
    }

    #[test]
    fn paged_rows_append_and_page_geometry() {
        let mut p: PagedRows<i8> = PagedRows::with_page_rows(4, 3);
        assert!(p.is_empty());
        assert_eq!(p.pages(), 0);
        assert_eq!(p.bytes_allocated(), 0);
        for r in 0..7i8 {
            let row = p.append_row();
            row.copy_from_slice(&[r, r + 1, r + 2, r + 3]);
        }
        assert_eq!(p.len(), 7);
        assert_eq!(p.elems(), 28);
        // 7 rows at 3 rows/page → 3 pages (tail holds 1 row).
        assert_eq!(p.pages(), 3);
        assert_eq!(p.bytes_allocated(), 3 * 3 * 4);
        // Page list: full, full, trimmed tail.
        let pl = p.page_list();
        assert_eq!(pl.iter().map(|s| s.len()).collect::<Vec<_>>(), vec![12, 12, 4]);
        // Rows and elementwise iteration see the appended order.
        assert_eq!(p.row(4), &[4, 5, 6, 7]);
        let flat: Vec<i8> = p.iter().copied().collect();
        assert_eq!(flat.len(), 28);
        assert_eq!(&flat[16..20], &[4, 5, 6, 7]);
        // for_each_mut touches exactly the valid elements.
        let mut q = p.clone();
        let mut touched = 0;
        q.for_each_mut(|_| touched += 1);
        assert_eq!(touched, 28);
    }

    #[test]
    fn paged_rows_clone_is_cow_and_equal() {
        let mut p: PagedRows<f32> = PagedRows::with_page_rows(2, 2);
        for r in 0..5 {
            p.append_row().copy_from_slice(&[r as f32, -(r as f32)]);
        }
        let q = p.clone();
        assert_eq!(q.len(), 5);
        // The clone aliases every page (copy-on-write, not a deep copy).
        assert_eq!(q.shared_pages(), 3);
        let a: Vec<f32> = p.iter().copied().collect();
        let b: Vec<f32> = q.iter().copied().collect();
        assert_eq!(a, b);
        // Mutating the clone forks the tail page and leaves the original
        // untouched.
        let mut q = q;
        q.append_row().copy_from_slice(&[9.0, 9.0]);
        assert_eq!(p.len(), 5);
        assert_eq!(q.len(), 6);
        assert_eq!(p.row(4), &[4.0, -4.0]);
        // Full pages are still shared; only the diverged tail forked.
        assert_eq!(q.shared_pages(), 2);
    }

    #[test]
    fn page_pool_recycles_released_pages() {
        // Use an unusual capacity so concurrent tests can't interfere with
        // the exact-capacity match.
        let cap = 7 * 13;
        let pool = <i8 as PageElem>::pool();
        let r0 = pool.stats().recycled;
        let page = pool.acquire(cap);
        pool.release(page);
        let _page2 = pool.acquire(cap);
        let r1 = pool.stats().recycled;
        assert!(r1 > r0, "released page of a unique capacity must be reused");
    }

    #[test]
    fn dropping_paged_rows_returns_pages_to_pool() {
        let d = 11; // unusual width → unusual page capacity
        let r0 = <f32 as PageElem>::pool().stats().recycled;
        {
            let mut p: PagedRows<f32> = PagedRows::with_page_rows(d, 3);
            for _ in 0..4 {
                p.append_row().fill(1.0);
            }
        } // dropped: 2 pages released
        let mut q: PagedRows<f32> = PagedRows::with_page_rows(d, 3);
        for _ in 0..4 {
            q.append_row().fill(2.0);
        }
        let r1 = <f32 as PageElem>::pool().stats().recycled;
        assert!(r1 >= r0 + 2, "the dropped store's pages must be recycled");
    }

    #[test]
    fn share_prefix_aliases_pages_and_forks_on_append() {
        let mut donor: PagedRows<i8> = PagedRows::with_page_rows(2, 2);
        for r in 0..5i8 {
            donor.append_row().copy_from_slice(&[r, -r]);
        }
        // Page-aligned share: 4 rows = 2 full pages, tail page not shared.
        let mut adopter = donor.share_prefix(4);
        assert_eq!(adopter.len(), 4);
        assert_eq!(adopter.pages(), 2);
        assert_eq!(adopter.shared_pages(), 2);
        assert_eq!(donor.shared_pages(), 2, "donor's tail page stays private");
        let a: Vec<i8> = adopter.iter().copied().collect();
        let b: Vec<i8> = donor.iter().take(8).copied().collect();
        assert_eq!(a, b);
        // Aligned adoption appends into a fresh page — no fork needed: both
        // shared pages stay shared (a fork would have unshared one).
        let forks0 = <i8 as PageElem>::pool().stats().cow_forks;
        adopter.append_row().copy_from_slice(&[7, 7]);
        assert_eq!(adopter.shared_pages(), 2);
        assert_eq!(donor.shared_pages(), 2);

        // Mid-page share: the tail page is aliased, so the first divergent
        // append must fork it — and the donor must not see the new row.
        let mut mid = donor.share_prefix(3);
        assert_eq!(mid.pages(), 2);
        assert_eq!(mid.shared_pages(), 2);
        mid.append_row().copy_from_slice(&[9, 9]);
        assert!(<i8 as PageElem>::pool().stats().cow_forks > forks0);
        assert_eq!(mid.row(3), &[9, 9]);
        assert_eq!(donor.row(3), &[3, -3], "donor bytes must survive the fork");
    }

    #[test]
    fn rescale_on_sharer_forks_instead_of_rewriting_shared_pages() {
        // Donor and adopter share an INT8 prefix; the adopter then appends
        // a large-magnitude row, so *its* running scale grows and its
        // resident rows re-map. The remap must fork the shared pages: the
        // donor's bytes (and scale) are untouched.
        let mut donor = KvState::with_page_rows(PipelineKind::IntAttention, 2, 2);
        let rows = MatF32::from_vec(4, 2, vec![0.5, -0.25, 0.25, 0.5, -0.5, 0.125, 0.5, 0.25]);
        donor.append(&rows, &rows);
        let mut adopter = donor.share_prefix(4);
        assert_eq!(adopter.shared_pages(), 4); // 2 pages per side × K and V
        let donor_bytes: Vec<i8> = donor.as_int8().k.data.iter().copied().collect();
        let big = MatF32::from_vec(1, 2, vec![4.0, 1.0]);
        adopter.append(&big, &big);
        let s = adopter.as_int8();
        assert_eq!(s.k.rescales, 1, "amax grew: the adopter must re-map");
        // The donor's resident bytes and scale are exactly as before.
        let donor_after: Vec<i8> = donor.as_int8().k.data.iter().copied().collect();
        assert_eq!(donor_bytes, donor_after);
        assert!((donor.as_int8().k.amax - 0.5).abs() < 1e-12);
        // Nothing is shared anymore: every shared page was forked.
        assert_eq!(adopter.shared_pages(), 0);
        assert_eq!(donor.shared_pages(), 0);
    }

    #[test]
    fn share_prefix_survives_donor_drop_and_unshares_at_last_holder() {
        // Intermediate drops must not release pages a sharer still
        // references, and once only one holder remains nothing may still be
        // marked shared. (The exact pool-outstanding leak check lives in
        // tests/kv_paging.rs, where the whole test binary serializes its
        // pool access — unit tests here run concurrently with other
        // page-allocating tests, so counter-delta assertions would race.)
        let d = 13;
        let mut donor: PagedRows<f32> = PagedRows::with_page_rows(d, 2);
        for _ in 0..6 {
            donor.append_row().fill(1.0);
        }
        let a = donor.share_prefix(6);
        let b = donor.share_prefix(4);
        drop(donor); // sharers a and b still hold every page they see
        let got: Vec<f32> = a.iter().copied().collect();
        assert_eq!(got.len(), 6 * d);
        assert!(got.iter().all(|&x| x == 1.0));
        assert_eq!(a.shared_pages(), 2, "first two pages still shared with b");
        drop(a);
        let got: Vec<f32> = b.iter().copied().collect();
        assert!(got.iter().all(|&x| x == 1.0));
        assert_eq!(b.shared_pages(), 0, "sole surviving holder owns its pages");
    }

    #[test]
    fn kvstate_share_prefix_copies_scales_for_every_storage() {
        let mut rng = Pcg64::seed_from_u64(21);
        let rows = rand_mat(&mut rng, 6, 4);
        for kind in [PipelineKind::Fp32, PipelineKind::Fp16, PipelineKind::IntAttention] {
            let mut donor = KvState::with_page_rows(kind, 4, 2);
            donor.append(&rows, &rows);
            let shared = donor.share_prefix(6);
            assert_eq!(shared.len(), 6);
            assert_eq!(shared.storage_name(), donor.storage_name());
            assert!(shared.shared_pages() > 0);
            if let (KvState::Int8(a), KvState::Int8(b)) = (&donor, &shared) {
                assert_eq!(a.k.scale, b.k.scale);
                assert_eq!(a.v.amax, b.v.amax);
                let x: Vec<i8> = a.k.data.iter().copied().collect();
                let y: Vec<i8> = b.k.data.iter().copied().collect();
                assert_eq!(x, y);
            }
        }
    }

    #[test]
    fn int8_running_scale_matches_one_shot_quantization() {
        // Appending chunk-by-chunk must end with the same scale one-shot
        // per-tensor quantization of the concatenated rows produces.
        let mut rng = Pcg64::seed_from_u64(1);
        let full = rand_mat(&mut rng, 24, 8);
        let mut st = KvState::new(PipelineKind::IntAttention, 8);
        for start in (0..24).step_by(6) {
            let chunk = MatF32::from_vec(6, 8, full.as_slice()[start * 8..(start + 6) * 8].to_vec());
            st.append(&chunk, &chunk);
        }
        let s = st.as_int8();
        let one_shot = quantize_i8(&full);
        assert_eq!(s.len(), 24);
        assert!((s.k.scale - one_shot.scale).abs() < 1e-12, "{} vs {}", s.k.scale, one_shot.scale);
        // Rows quantized after the amax stopped growing are bit-identical to
        // one-shot; earlier rows pick up ≤ half an LSB of extra rounding per
        // re-scale event (3 chunks after the first ⇒ ≤ 2 LSB here).
        for (a, b) in s.k.data.iter().zip(one_shot.data.as_slice()) {
            assert!((*a as i32 - *b as i32).abs() <= 2, "{a} vs {b}");
        }
    }

    #[test]
    fn int8_append_identical_across_page_sizes() {
        // The same append schedule (including re-scale events) must leave
        // byte-identical quantized rows and identical scales at any page
        // size — pages are pure layout.
        let mut rng = Pcg64::seed_from_u64(9);
        let chunks: Vec<MatF32> = (0..5)
            .map(|i| {
                let mut m = rand_mat(&mut rng, 3, 8);
                for x in m.as_mut_slice() {
                    *x *= 1.0 + i as f32; // ramp forces re-scales
                }
                m
            })
            .collect();
        // 1024 ≥ the 15 rows appended: that state keeps a single page per
        // side, i.e. the pre-paging contiguous layout.
        let mut states: Vec<KvState> = [1usize, 2, 64, 1024]
            .iter()
            .map(|&pr| KvState::with_page_rows(PipelineKind::IntAttention, 8, pr))
            .collect();
        for c in &chunks {
            for st in states.iter_mut() {
                st.append(c, c);
            }
        }
        let oracle = states.last().unwrap().as_int8();
        let want_k: Vec<i8> = oracle.k.data.iter().copied().collect();
        for st in &states[..3] {
            let s = st.as_int8();
            assert_eq!(s.k.scale, oracle.k.scale);
            assert_eq!(s.k.rescales, oracle.k.rescales);
            let got: Vec<i8> = s.k.data.iter().copied().collect();
            assert_eq!(got, want_k, "page size {}", s.k.data.page_rows());
        }
    }

    #[test]
    fn rescale_fires_only_when_amax_grows() {
        let mut st = KvState::new(PipelineKind::IntAttention, 2);
        let small = MatF32::from_vec(1, 2, vec![0.5, -0.25]);
        let big = MatF32::from_vec(1, 2, vec![4.0, 1.0]);
        st.append(&small, &small);
        assert_eq!(st.as_int8().k.rescales, 0);
        st.append(&small, &small); // same magnitude: no rescale
        assert_eq!(st.as_int8().k.rescales, 0);
        st.append(&big, &big); // amax grows 0.5 → 4.0: resident rows re-map
        let s = st.as_int8();
        assert_eq!(s.k.rescales, 1);
        assert!((s.k.amax - 4.0).abs() < 1e-12);
        // Old rows re-mapped onto the wider grid: 0.5 at scale 4/127 → 16.
        assert_eq!(s.k.data.row(0)[0], 16);
        st.append(&small, &small); // shrinking magnitudes never rescale
        assert_eq!(st.as_int8().k.rescales, 1);
    }

    #[test]
    fn zero_rows_are_safe() {
        let mut st = KvState::new(PipelineKind::QuantOnly, 4);
        let z = MatF32::zeros(3, 4);
        st.append(&z, &z);
        let s = st.as_int8();
        assert_eq!(s.k.scale, 1.0);
        assert!(s.k.data.iter().all(|&x| x == 0));
        // First nonzero append after zeros must not count as a "rescale"
        // (there is nothing to re-map).
        let nz = MatF32::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]);
        st.append(&nz, &nz);
        assert_eq!(st.as_int8().k.rescales, 0);
        assert_eq!(st.len(), 4);
    }

    #[test]
    fn bytes_report_allocated_page_capacity() {
        let mut rng = Pcg64::seed_from_u64(2);
        let rows = rand_mat(&mut rng, 10, 16);
        // Explicit page size 4: 10 rows → 3 pages per side.
        let mut f32s = KvState::with_page_rows(PipelineKind::Fp32, 16, 4);
        let mut f16s = KvState::with_page_rows(PipelineKind::Fp16, 16, 4);
        let mut i8s = KvState::with_page_rows(PipelineKind::IntAttention, 16, 4);
        for s in [&mut f32s, &mut f16s, &mut i8s] {
            s.append(&rows, &rows);
        }
        // Capacity is pages × page bytes — exact, includes tail slack.
        assert_eq!(f32s.bytes(), 2 * 3 * 4 * 16 * 4);
        assert_eq!(f16s.bytes(), 2 * 3 * 4 * 16 * 2);
        // INT8: pages + 56 B of scale/stat bookkeeping.
        assert_eq!(i8s.bytes(), 2 * 3 * 4 * 16 + 56);
        for s in [&f32s, &f16s, &i8s] {
            assert_eq!(s.pages(), 6);
            assert_eq!(s.capacity_rows(), 24);
            assert_eq!(s.rows_stored(), 20);
        }
        // The linear per-token payload estimate is unchanged.
        assert_eq!(kv_bytes_per_token(PipelineKind::Fp32, 16), 128);
        assert_eq!(kv_bytes_per_token(PipelineKind::Fp16, 16), 64);
        assert_eq!(kv_bytes_per_token(PipelineKind::IntAttention, 16), 32);
    }

    #[test]
    fn exaq_stats_accumulate() {
        let mut st = ExaqRunningStats::default();
        assert_eq!(st.sigma(), 0.0);
        // Two batches of {0, 2} → mean 1, var 1.
        st.merge(2.0, 4.0, 2);
        st.merge(2.0, 4.0, 2);
        assert!((st.sigma() - 1.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "different pipeline kind")]
    fn cross_kind_access_panics() {
        let st = KvState::new(PipelineKind::Fp32, 4);
        let _ = st.as_int8();
    }
}
