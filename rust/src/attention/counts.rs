//! Op-count builders shared by the pipelines: given the shape of a forward
//! pass, produce the [`OpCounts`] each stage contributes. Centralizing the
//! accounting keeps the Figure 8 energy comparison consistent across
//! pipelines.

use crate::energy::OpCounts;
use crate::softmax::index_softmax::Mask;

/// Number of (row, col) pairs the mask admits for an `m×l` logit matrix.
pub fn valid_positions(m: usize, l: usize, mask: Mask) -> u64 {
    match mask {
        Mask::None => (m * l) as u64,
        Mask::Causal => {
            debug_assert_eq!(m, l);
            (l as u64 * (l as u64 + 1)) / 2
        }
        Mask::CausalFrom(_) => (0..m).map(|r| mask.valid_cols(r, l) as u64).sum(),
    }
}

/// Dynamic INT8 quantization of Q, K, V (eq. 2–3): one abs-max scan plus one
/// scale-and-round per element of each tensor.
pub fn quantize_qkv(m: usize, l: usize, d: usize) -> OpCounts {
    let elems = ((m + 2 * l) * d) as u64;
    OpCounts {
        fp32_alu: 2 * elems,          // abs+max scan, then mul-by-inv-scale
        dtype_conv: elems,            // round+cast to i8
        mem_bytes: elems * (4 + 1),   // read f32, write i8
        ..Default::default()
    }
}

/// Re-mapping resident INT8 K/V rows onto a wider grid when a state's
/// running abs-max grows (`Int8Side::append`'s re-scale path): one f32
/// multiply plus a round/cast per resident element. Rare — the abs-max is a
/// running maximum — but counted so stage timings and the energy model stay
/// consistent on the steps where it fires.
pub fn kv_rescale(elems: u64) -> OpCounts {
    OpCounts {
        fp32_alu: elems,
        dtype_conv: elems,
        mem_bytes: elems * 2, // read i8, write i8
        ..Default::default()
    }
}

/// FP16 encode of Q, K, V.
pub fn encode_qkv_f16(m: usize, l: usize, d: usize) -> OpCounts {
    let elems = ((m + 2 * l) * d) as u64;
    OpCounts {
        dtype_conv: elems,
        mem_bytes: elems * (4 + 2),
        ..Default::default()
    }
}

/// The `Q·Kᵀ` GEMM over all `m×l` outputs (both pipelines compute the full
/// rectangle; causal skipping is a later optimization in both the paper's
/// kernels and ours).
pub fn qk_gemm(m: usize, l: usize, d: usize, elem_bytes: u64, out_bytes: u64) -> OpCounts {
    let macs = (m * l * d) as u64;
    OpCounts {
        mem_bytes: ((m + l) * d) as u64 * elem_bytes + (m * l) as u64 * out_bytes,
        ..Default::default()
    }
    .with_macs(macs, elem_bytes)
}

/// The `P·V` GEMM; `nnz` is the number of probability entries actually
/// aggregated (IntAttention skips exact zeros — the §3.1 sparsity).
pub fn pv_gemm(nnz: u64, l: usize, d: usize, elem_bytes: u64, out_bytes: u64) -> OpCounts {
    let macs = nnz * d as u64;
    OpCounts {
        mem_bytes: (l * d) as u64 * elem_bytes + nnz + (l * d) as u64 * out_bytes,
        ..Default::default()
    }
    .with_macs(macs, elem_bytes)
}

impl OpCounts {
    fn with_macs(mut self, macs: u64, elem_bytes: u64) -> OpCounts {
        match elem_bytes {
            1 => self.int8_mac += macs,
            2 => self.fp16_mac += macs,
            _ => self.fp32_mac += macs,
        }
        self
    }
}

/// FP32 softmax over `valid` positions in `rows` rows (eq. 6): max scan,
/// subtract, exp, sum, divide-by-row.
pub fn fp32_softmax(valid: u64, rows: u64) -> OpCounts {
    OpCounts {
        fp32_alu: 4 * valid,      // max cmp + sub + sum-add + scale-mul
        fp32_exp: valid,
        fp32_div: rows,           // one reciprocal per row
        mem_bytes: valid * 8,     // read + write f32
        ..Default::default()
    }
}

/// Dequantize INT32 logits → FP32 (the detour's first conversion).
pub fn dequantize_logits(valid: u64) -> OpCounts {
    OpCounts {
        dtype_conv: valid,
        fp32_alu: valid,          // ×α
        mem_bytes: valid * 8,     // read i32, write f32
        ..Default::default()
    }
}

/// Requantize FP32 probabilities → INT8/UINT8 (the detour's second conversion).
pub fn requantize_probs(valid: u64) -> OpCounts {
    OpCounts {
        dtype_conv: valid,
        fp32_alu: valid,          // ×127 or ×255
        mem_bytes: valid * 5,     // read f32, write 8-bit
        ..Default::default()
    }
}

/// IndexSoftmax over `valid` positions (§3.1–3.2): max scan + subtract +
/// clip (int32 ALU), multiply–shift index (int32 mul), LUT gather, sum add
/// (int32 ALU), and one multiply–shift normalize per element.
pub fn index_softmax(valid: u64, _rows: u64) -> OpCounts {
    OpCounts {
        int32_alu: 4 * valid,     // max cmp + sub + clip + sum
        int32_mul: 2 * valid,     // index mul-shift + normalize mul-shift
        lut_gather: valid,
        mem_bytes: valid * 6,     // read i32, write u8 (+ staging u8)
        ..Default::default()
    }
}

/// EXAQ softmax: integer max/sub + gather like IndexSoftmax, but an extra
/// global statistics pass (mean/var) and float normalization per element.
pub fn exaq_softmax(valid: u64, rows: u64) -> OpCounts {
    OpCounts {
        int32_alu: 2 * valid,
        fp32_alu: 3 * valid + 2 * valid, // stats pass + normalize mul
        lut_gather: valid,
        fp32_div: rows,
        dtype_conv: valid,               // ×255 requantize of P
        mem_bytes: valid * 10,
        ..Default::default()
    }
}

/// EXAQ softmax on the fused decode walk: the same integer max/sub, LUT
/// gathers and float accumulation as [`exaq_softmax`], but the Δ-statistics
/// ride the same single pass (no separate stats sweep reads) and the ×255
/// `P̂` requantization is gone entirely — the float accumulator is
/// normalized once per output *lane* instead of rounding every probability,
/// so the per-element dtype conversion disappears from the hot loop.
pub fn exaq_softmax_fused(valid: u64, rows: u64) -> OpCounts {
    OpCounts {
        int32_alu: 2 * valid,
        fp32_alu: 3 * valid + 2 * valid,
        lut_gather: valid,
        fp32_div: rows,
        mem_bytes: valid * 9, // no P̂ row written back
        ..Default::default()
    }
}

/// Final output rescale (`s_V/255 · P̂V̂` or f16→f32 restore).
pub fn output_rescale(m: usize, d: usize) -> OpCounts {
    let elems = (m * d) as u64;
    OpCounts {
        dtype_conv: elems,
        fp32_alu: elems,
        mem_bytes: elems * 8,
        ..Default::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valid_positions_modes() {
        assert_eq!(valid_positions(4, 8, Mask::None), 32);
        assert_eq!(valid_positions(4, 4, Mask::Causal), 10);
        // Offset causal: rows at absolute positions 2..6 over 6 keys →
        // 3 + 4 + 5 + 6 valid entries.
        assert_eq!(valid_positions(4, 6, Mask::CausalFrom(2)), 18);
        // Offset 0 matches plain causal.
        assert_eq!(valid_positions(4, 4, Mask::CausalFrom(0)), 10);
    }

    #[test]
    fn qk_gemm_counts_macs_by_dtype() {
        let c8 = qk_gemm(16, 16, 64, 1, 4);
        assert_eq!(c8.int8_mac, 16 * 16 * 64);
        assert_eq!(c8.fp32_mac, 0);
        let c32 = qk_gemm(16, 16, 64, 4, 4);
        assert_eq!(c32.fp32_mac, 16 * 16 * 64);
        let c16 = qk_gemm(16, 16, 64, 2, 4);
        assert_eq!(c16.fp16_mac, 16 * 16 * 64);
    }

    #[test]
    fn softmax_detour_has_conversions_but_index_softmax_does_not() {
        let v = 1000;
        let detour_convs =
            dequantize_logits(v).dtype_conv + requantize_probs(v).dtype_conv;
        assert_eq!(detour_convs, 2 * v);
        assert_eq!(index_softmax(v, 10).dtype_conv, 0);
        assert_eq!(index_softmax(v, 10).fp32_exp, 0);
        assert_eq!(fp32_softmax(v, 10).fp32_exp, v);
    }

    #[test]
    fn fused_exaq_drops_the_requantize_conversion() {
        assert_eq!(exaq_softmax(500, 1).dtype_conv, 500);
        assert_eq!(exaq_softmax_fused(500, 1).dtype_conv, 0);
        assert_eq!(exaq_softmax_fused(500, 1).lut_gather, 500);
    }

    #[test]
    fn pv_sparsity_reduces_macs() {
        let dense = pv_gemm(1000, 100, 64, 1, 4);
        let sparse = pv_gemm(400, 100, 64, 1, 4);
        assert!(sparse.int8_mac < dense.int8_mac);
    }
}
