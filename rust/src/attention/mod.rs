//! The five attention pipelines the paper evaluates (§4.1: FP32, FP16,
//! INT8 Quant-Only, IntAttention) plus the EXAQ ablation pipelines.
//!
//! Every pipeline implements [`AttentionPipeline`]: FP32 in/out (`Q, K, V`
//! are `M×d` / `L×d` / `L×d` row-major, `O` is `M×d`), with the internal
//! dataflow of the respective method. Each forward pass is instrumented
//! with per-stage wall-clock ([`StageTimes`]) and op counters
//! ([`OpCounts`]) — the raw data for Figure 2, Figure 8 and Table 8.

pub mod counts;
pub mod fp32;
pub mod fp16;
pub mod quant_only;
pub mod int_attention;
pub mod exaq_pipe;

use crate::energy::OpCounts;
use crate::softmax::index_softmax::{IndexSoftmaxConfig, Mask};
use crate::tensor::MatF32;
use crate::util::timer::StageTimes;

pub use crate::softmax::index_softmax::Mask as AttentionMask;

/// Static configuration of an attention head computation.
#[derive(Clone, Copy, Debug)]
pub struct AttentionConfig {
    /// Number of key/value positions `L`.
    pub seq_len: usize,
    /// Head dimension `d`.
    pub head_dim: usize,
    /// Masking mode (causal for decoder prefill, none for encoders/decode).
    pub mask: Mask,
    /// Worker threads for the GEMM drivers.
    pub threads: usize,
    /// IndexSoftmax hyperparameters (used by the IntAttention pipeline).
    pub isx: IndexSoftmaxConfig,
}

impl AttentionConfig {
    pub fn new(seq_len: usize, head_dim: usize) -> Self {
        AttentionConfig {
            seq_len,
            head_dim,
            mask: Mask::None,
            threads: 1,
            isx: IndexSoftmaxConfig::default(),
        }
    }

    pub fn causal(mut self) -> Self {
        self.mask = Mask::Causal;
        self
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }

    pub fn with_isx(mut self, isx: IndexSoftmaxConfig) -> Self {
        self.isx = isx;
        self
    }

    /// FLOP count of the two GEMMs (the normalization used for the GFLOP/s
    /// plots, Figures 6–7): `2·2·L_q·L_k·d`.
    pub fn gemm_flops(&self, q_rows: usize) -> u64 {
        2 * 2 * q_rows as u64 * self.seq_len as u64 * self.head_dim as u64
    }
}

/// Which pipeline (paper §4.1 naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    Fp32,
    Fp16,
    QuantOnly,
    IntAttention,
    /// EXAQ softmax inside the integer pipeline, INT2 LUT.
    ExaqInt2,
    /// EXAQ softmax inside the integer pipeline, INT3 LUT.
    ExaqInt3,
}

impl PipelineKind {
    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::Fp32 => "FP32",
            PipelineKind::Fp16 => "FP16",
            PipelineKind::QuantOnly => "Quant-Only",
            PipelineKind::IntAttention => "IntAttention",
            PipelineKind::ExaqInt2 => "EXAQ(INT2)",
            PipelineKind::ExaqInt3 => "EXAQ(INT3)",
        }
    }

    /// The four headline pipelines of Figures 6–8 / Table 8.
    pub fn headline() -> [PipelineKind; 4] {
        [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
        ]
    }

    pub fn parse(s: &str) -> Option<PipelineKind> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(PipelineKind::Fp32),
            "fp16" => Some(PipelineKind::Fp16),
            "quant-only" | "quantonly" | "int8" => Some(PipelineKind::QuantOnly),
            "intattention" | "int" | "intattn" => Some(PipelineKind::IntAttention),
            "exaq2" | "exaq-int2" => Some(PipelineKind::ExaqInt2),
            "exaq3" | "exaq-int3" => Some(PipelineKind::ExaqInt3),
            _ => None,
        }
    }
}

/// One attention head computation with instrumentation.
pub trait AttentionPipeline: Send {
    fn kind(&self) -> PipelineKind;

    fn config(&self) -> &AttentionConfig;

    /// Compute `O = Attention(Q, K, V)` with the configured mask.
    /// `q` is `M×d`; `k`, `v` are `L×d` with `L == config().seq_len`.
    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32;

    /// Per-stage wall clock accumulated since the last [`reset_stats`].
    fn stage_times(&self) -> &StageTimes;

    /// Op counters accumulated since the last [`reset_stats`].
    fn op_counts(&self) -> &OpCounts;

    fn reset_stats(&mut self);

    fn name(&self) -> &'static str {
        self.kind().name()
    }
}

/// Factory for a pipeline of the given kind.
pub fn build_pipeline(kind: PipelineKind, cfg: AttentionConfig) -> Box<dyn AttentionPipeline> {
    match kind {
        PipelineKind::Fp32 => Box::new(fp32::Fp32Attention::new(cfg)),
        PipelineKind::Fp16 => Box::new(fp16::Fp16Attention::new(cfg)),
        PipelineKind::QuantOnly => Box::new(quant_only::QuantOnlyAttention::new(cfg)),
        PipelineKind::IntAttention => Box::new(int_attention::IntAttention::new(cfg)),
        PipelineKind::ExaqInt2 => Box::new(exaq_pipe::ExaqAttention::new(
            cfg,
            crate::softmax::exaq::ExaqConfig::int2(),
        )),
        PipelineKind::ExaqInt3 => Box::new(exaq_pipe::ExaqAttention::new(
            cfg,
            crate::softmax::exaq::ExaqConfig::int3(),
        )),
    }
}

/// Shared shape validation for all pipelines.
pub(crate) fn validate_shapes(cfg: &AttentionConfig, q: &MatF32, k: &MatF32, v: &MatF32) {
    assert_eq!(q.cols(), cfg.head_dim, "Q head_dim");
    assert_eq!(k.cols(), cfg.head_dim, "K head_dim");
    assert_eq!(v.cols(), cfg.head_dim, "V head_dim");
    assert_eq!(k.rows(), cfg.seq_len, "K seq_len");
    assert_eq!(v.rows(), cfg.seq_len, "V seq_len");
    if cfg.mask == Mask::Causal {
        assert_eq!(
            q.rows(),
            cfg.seq_len,
            "causal mask requires square attention (q rows == seq_len)"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
        ] {
            assert_eq!(PipelineKind::parse(k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(PipelineKind::parse("int"), Some(PipelineKind::IntAttention));
        assert_eq!(PipelineKind::parse("bogus"), None);
    }

    #[test]
    fn config_builders() {
        let cfg = AttentionConfig::new(128, 64).causal().with_threads(4);
        assert_eq!(cfg.seq_len, 128);
        assert_eq!(cfg.mask, Mask::Causal);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.gemm_flops(128), 2 * 2 * 128 * 128 * 64);
    }

    #[test]
    fn factory_builds_every_kind() {
        let cfg = AttentionConfig::new(16, 8);
        for k in [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
            PipelineKind::ExaqInt2,
            PipelineKind::ExaqInt3,
        ] {
            let p = build_pipeline(k, cfg);
            assert_eq!(p.kind(), k);
        }
    }
}
