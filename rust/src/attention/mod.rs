//! The five attention pipelines the paper evaluates (§4.1: FP32, FP16,
//! INT8 Quant-Only, IntAttention) plus the EXAQ ablation pipelines.
//!
//! Every pipeline implements [`AttentionPipeline`], which exposes **two**
//! computation modes:
//!
//! * **One-shot** — [`AttentionPipeline::forward`]: FP32 in/out (`Q, K, V`
//!   are `M×d` / `L×d` / `L×d` row-major, `O` is `M×d`) with the internal
//!   dataflow of the respective method. This is the operator benchmark path
//!   (Figures 2, 6–8, Table 8).
//! * **Stateful** — [`AttentionPipeline::begin_state`] →
//!   [`AttentionPipeline::prefill`] / [`AttentionPipeline::decode_step`]:
//!   the serving path. A per-sequence [`KvState`] keeps K/V resident **in
//!   the pipeline's native operand format** (INT8 rows + running scales for
//!   the integer pipelines, native rows for FP32/FP16), so a decode step
//!   appends and quantizes exactly one row instead of re-quantizing the
//!   whole history — O(1) conversion work per token instead of O(L·d).
//!   Chunked prefill is the same call repeated: each `prefill` block is
//!   masked causally at its absolute position offset
//!   ([`Mask::CausalFrom`]).
//! * **Batched decode** — [`AttentionPipeline::decode_step_batch`]: one
//!   decode step for `B` independent sequences at once. Bit-identical to
//!   `B` sequential `decode_step` calls (per-sequence scales, statistics
//!   and offsets), but the `B` per-sequence `1×L_b` GEMM pairs run as
//!   grouped kernel launches that spread the thread pool across sequences
//!   — the serving engine's continuous-batching rounds stop being
//!   memory-bound at batch 1.
//!
//! Both modes are instrumented with per-stage wall-clock ([`StageTimes`])
//! and op counters ([`OpCounts`]) — the raw data for Figure 2, Figure 8,
//! Table 8 and the decode-throughput bench.
//!
//! ## Fused flash-decode (integer pipelines)
//!
//! Decode is memory-bound, and the unfused step walks each sequence's KV
//! page list **three** times per token: the paged `Q̂K̂ᵀ` materializes a full
//! `1×L` score row, IndexSoftmax normalizes it, and the paged `P̂V̂` reads it
//! back. With [`AttentionConfig::fused_decode`] on (the default; env
//! `INTATTN_FUSED_DECODE=0` turns it off, snapshotted once per process like
//! the page size), the IntAttention and EXAQ pipelines instead run the
//! two-phase online walk ([`crate::gemm::fused_decode_i8`] /
//! [`crate::gemm::fused_decode_exaq`]): phase 1 streams the `Q̂K̂ᵀ` logit
//! tiles through a running-max fold
//! ([`crate::softmax::index_softmax::OnlineIndexRow`] /
//! [`crate::softmax::exaq::ExaqOnlineRow`]); phase 2 re-walks the zipped
//! K̂/V̂ pages with the max pinned, gathering each `Ê` against the *final*
//! max straight onto an O(d) integer accumulator (K̂ is read twice — the
//! classic flash recompute trade for never materializing an `L`-length
//! row). Every partial quantity — max, `ΣÊ`, nnz, accumulator lanes — is
//! an associative integer fold, so the page list also splits *within* a
//! sequence: [`AttentionConfig::decode_split`] (env `INTATTN_DECODE_SPLIT`,
//! auto-sized from the pool by default) cuts each sequence's page list into
//! that many contiguous spans, the span jobs fan out across the pool
//! ([`crate::gemm::par_fused_decode_i8_spans`] /
//! [`crate::gemm::par_fused_decode_exaq_spans`]), span maxes merge and
//! rebroadcast between the two phases, and the partial triples merge by
//! plain integer adds afterwards — byte-identical to the sequential walk at
//! every page size, pool width, batch split **and** span split, so
//! batch-of-1 deep-context decode finally scales with threads.
//!
//! **Fidelity contract vs the unfused oracle.** The unfused path rounds
//! each probability to UINT8 (`P̂ = round(255·Ê/ΣÊ)`) *before* the `P̂V̂`
//! sum; the fused path accumulates un-normalized `Ê·V̂` (the gathered `Ê`
//! are identical — both sides index the LUT against the same final max) and
//! applies one final `round(255·acc/ΣÊ)` per output lane. The two paths are
//! therefore **bit-exact only where that rounding reorder is degenerate** —
//! a single surviving entry (e.g. the first decode token: `acc = 255·V̂`,
//! `ΣÊ = 255`) — and elsewhere agree to a documented ε: per-step cosine
//! ≥ 0.999 against the unfused oracle and per-lane error bounded by a few
//! output quanta (asserted with explicit bounds in
//! `tests/decode_equivalence.rs` and `tests/fused_decode.rs`). EXAQ's fused
//! form additionally skips the ×255 P̂ requantization entirely (per-bucket
//! integer `V̂` sums combined through the f32 LUT once at the end — one
//! fewer dtype conversion per element, see [`counts::exaq_softmax_fused`])
//! and derives its dynamic clip from the *pre-step* running σ, merging the
//! step's exact Δ-moments after the walk (the unfused path folds the new
//! row's stats in before clipping — a stale-by-one-token clip difference
//! that the equivalence tests bound). Quant-Only keeps the unfused
//! three-pass dataflow: its purpose is to measure the FP32-softmax
//! conversion detour, which a fused integer walk would define away.
//!
//! ## Online-tiled prefill (integer pipelines)
//!
//! The same flash structure is the prefill default:
//! [`AttentionConfig::tiled_prefill`] (env `INTATTN_TILED_PREFILL`, on
//! unless disabled) routes IntAttention and EXAQ prefill through
//! [`crate::gemm::tiled_prefill_i8`] /
//! [`crate::gemm::tiled_prefill_exaq_stats`] +
//! [`crate::gemm::tiled_prefill_exaq_pv`]: per query row, the KV pages are
//! walked in bounded tiles (max pass, `ΣÊ`/stats pass, normalize-and-`P̂V̂`
//! pass), so no `m×L` score block is ever allocated — the working set is
//! O(tile + d) per row at any context length. Because every pass gathers
//! against the final row max with exactly the materialized path's integer
//! ops in the same order, tiled IndexSoftmax prefill is **bit-for-bit**
//! equal to the unfused oracle (EXAQ agrees to cosine ≥ 0.999: its
//! block-global dynamic clip is re-derived from exact integer Δ-moments,
//! which can round the f64 clip differently). Query rows fan out across
//! the pool in [`crate::gemm::ROW_BLOCK`]-row jobs. The materialized path
//! stays as the oracle (`INTATTN_TILED_PREFILL=0`), and Quant-Only keeps it
//! unconditionally.

pub mod counts;
pub mod state;
pub mod fp32;
pub mod fp16;
pub mod quant_only;
pub mod int_attention;
pub mod exaq_pipe;

use crate::energy::OpCounts;
use crate::softmax::index_softmax::{IndexSoftmaxConfig, Mask};
use crate::tensor::MatF32;
use crate::util::threadpool::ParallelPool;
use crate::util::timer::StageTimes;

pub use crate::softmax::index_softmax::Mask as AttentionMask;
pub use state::{
    kv_bytes_per_token, kv_page_rows, page_pool_stats, KvState, PagePoolStats, PagedRows,
};

/// Static configuration of an attention head computation.
#[derive(Clone, Copy, Debug)]
pub struct AttentionConfig {
    /// Number of key/value positions `L`.
    pub seq_len: usize,
    /// Head dimension `d`.
    pub head_dim: usize,
    /// Masking mode (causal for decoder prefill, none for encoders/decode).
    pub mask: Mask,
    /// Persistent parallel runtime the GEMM drivers dispatch onto. Defaults
    /// to a single-thread (inline) pool; the serving path shares
    /// [`ParallelPool::global`], sized once from `INTATTN_THREADS`.
    pub pool: &'static ParallelPool,
    /// IndexSoftmax hyperparameters (used by the IntAttention pipeline).
    pub isx: IndexSoftmaxConfig,
    /// Use the fused one-page-walk decode path in the integer pipelines
    /// (see the module docs). Defaults to the process-wide
    /// [`fused_decode_default`] snapshot (`INTATTN_FUSED_DECODE`, on unless
    /// set to `0`/`false`/`off`); tests build both paths explicitly with
    /// [`Self::with_fused_decode`].
    pub fused_decode: bool,
    /// Page spans per sequence in the fused decode walk (`0` = auto-size
    /// from the pool and batch; see [`crate::gemm::decode_split_spans`]).
    /// Defaults to the process-wide [`decode_split_default`] snapshot
    /// (`INTATTN_DECODE_SPLIT`).
    pub decode_split: usize,
    /// Use the online-tiled prefill path in the integer pipelines (see the
    /// module docs). Defaults to the process-wide [`tiled_prefill_default`]
    /// snapshot (`INTATTN_TILED_PREFILL`, on unless set to
    /// `0`/`false`/`off`); tests build both paths explicitly with
    /// [`Self::with_tiled_prefill`].
    pub tiled_prefill: bool,
}

/// Process-wide fused-decode default: `INTATTN_FUSED_DECODE` snapshotted
/// once (with the other knobs, [`crate::util::env::knobs`]), on unless
/// explicitly disabled (parse policy:
/// [`crate::util::env::fused_decode_from`]).
pub fn fused_decode_default() -> bool {
    crate::util::env::knobs().fused_decode
}

/// Process-wide decode span-split default: `INTATTN_DECODE_SPLIT`
/// snapshotted once (`0` = auto; parse policy:
/// [`crate::util::env::decode_split_from`]).
pub fn decode_split_default() -> usize {
    crate::util::env::knobs().decode_split
}

/// Process-wide tiled-prefill default: `INTATTN_TILED_PREFILL` snapshotted
/// once, on unless explicitly disabled (parse policy:
/// [`crate::util::env::tiled_prefill_from`]).
pub fn tiled_prefill_default() -> bool {
    crate::util::env::knobs().tiled_prefill
}

impl AttentionConfig {
    pub fn new(seq_len: usize, head_dim: usize) -> Self {
        AttentionConfig {
            seq_len,
            head_dim,
            mask: Mask::None,
            pool: ParallelPool::sized(1),
            isx: IndexSoftmaxConfig::default(),
            fused_decode: fused_decode_default(),
            decode_split: decode_split_default(),
            tiled_prefill: tiled_prefill_default(),
        }
    }

    pub fn causal(mut self) -> Self {
        self.mask = Mask::Causal;
        self
    }

    /// Causal masking for a query block whose first row sits at absolute
    /// position `offset` (chunked prefill over a KV cache).
    pub fn causal_from(mut self, offset: usize) -> Self {
        self.mask = Mask::CausalFrom(offset);
        self
    }

    /// Convenience: dispatch onto the cached fixed-size pool of `t`
    /// computing threads ([`ParallelPool::sized`]); `t == 1` keeps every
    /// launch inline. Benches use this to pin thread-count configurations.
    pub fn with_threads(self, t: usize) -> Self {
        self.with_pool(ParallelPool::sized(t))
    }

    /// Dispatch onto an explicit pool (tests pass grain-1 pools to force
    /// real multi-worker dispatch on small shapes).
    pub fn with_pool(mut self, pool: &'static ParallelPool) -> Self {
        self.pool = pool;
        self
    }

    pub fn with_isx(mut self, isx: IndexSoftmaxConfig) -> Self {
        self.isx = isx;
        self
    }

    /// Force the fused (or unfused) decode path regardless of the process
    /// default — the equivalence tests and the `decode_fused` bench build
    /// both sides of the comparison this way.
    pub fn with_fused_decode(mut self, on: bool) -> Self {
        self.fused_decode = on;
        self
    }

    /// Force a fused-decode span-split width (`0` = auto by pool/batch;
    /// `1` = the sequential one-span walk). The page-parallel equivalence
    /// tests and the `decode_parallel_fused` bench sweep this.
    pub fn with_decode_split(mut self, split: usize) -> Self {
        self.decode_split = split;
        self
    }

    /// Force the tiled (or materialized) prefill path regardless of the
    /// process default — the prefill equivalence and allocation tests build
    /// both sides this way.
    pub fn with_tiled_prefill(mut self, on: bool) -> Self {
        self.tiled_prefill = on;
        self
    }

    /// FLOP count of the two GEMMs (the normalization used for the GFLOP/s
    /// plots, Figures 6–7): `2·2·L_q·L_k·d`.
    pub fn gemm_flops(&self, q_rows: usize) -> u64 {
        2 * 2 * q_rows as u64 * self.seq_len as u64 * self.head_dim as u64
    }
}

/// Which pipeline (paper §4.1 naming).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PipelineKind {
    Fp32,
    Fp16,
    QuantOnly,
    IntAttention,
    /// EXAQ softmax inside the integer pipeline, INT2 LUT.
    ExaqInt2,
    /// EXAQ softmax inside the integer pipeline, INT3 LUT.
    ExaqInt3,
}

impl PipelineKind {
    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::Fp32 => "FP32",
            PipelineKind::Fp16 => "FP16",
            PipelineKind::QuantOnly => "Quant-Only",
            PipelineKind::IntAttention => "IntAttention",
            PipelineKind::ExaqInt2 => "EXAQ(INT2)",
            PipelineKind::ExaqInt3 => "EXAQ(INT3)",
        }
    }

    /// The four headline pipelines of Figures 6–8 / Table 8.
    pub fn headline() -> [PipelineKind; 4] {
        [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
        ]
    }

    /// All six pipeline kinds (the decode-equivalence suite sweeps these).
    pub fn all() -> [PipelineKind; 6] {
        [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
            PipelineKind::ExaqInt2,
            PipelineKind::ExaqInt3,
        ]
    }

    pub fn parse(s: &str) -> Option<PipelineKind> {
        match s.to_ascii_lowercase().as_str() {
            "fp32" => Some(PipelineKind::Fp32),
            "fp16" => Some(PipelineKind::Fp16),
            "quant-only" | "quantonly" | "int8" => Some(PipelineKind::QuantOnly),
            "intattention" | "int" | "intattn" => Some(PipelineKind::IntAttention),
            "exaq2" | "exaq-int2" => Some(PipelineKind::ExaqInt2),
            "exaq3" | "exaq-int3" => Some(PipelineKind::ExaqInt3),
            _ => None,
        }
    }
}

/// One attention head computation with instrumentation.
pub trait AttentionPipeline: Send {
    fn kind(&self) -> PipelineKind;

    fn config(&self) -> &AttentionConfig;

    /// Compute `O = Attention(Q, K, V)` with the configured mask.
    /// `q` is `M×d`; `k`, `v` are `L×d` with `L == config().seq_len`.
    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32;

    /// Start an empty per-sequence KV state in this pipeline's native
    /// operand format. The state is owned by the caller (one per sequence
    /// per head) and threaded through [`prefill`](Self::prefill) /
    /// [`decode_step`](Self::decode_step).
    fn begin_state(&self) -> KvState {
        KvState::new(self.kind(), self.config().head_dim)
    }

    /// Append the block's `k`/`v` rows to `state` (converting them once into
    /// the resident format) and attend `q` over the entire history with a
    /// causal mask at the block's absolute offset: query row `r` sits at
    /// position `state.len() + r` (lengths taken *before* the append) and
    /// sees keys `0..=state.len() + r`.
    ///
    /// `q`, `k`, `v` are `m×d` with equal row counts. Returns `m×d` outputs.
    /// Chunked prefill is this call repeated; `config().seq_len` is ignored
    /// (the history length lives in the state).
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32;

    /// One decode step: append the single new K/V row and attend the single
    /// query row over the whole history (itself included). Equivalent to a
    /// 1-row [`prefill`](Self::prefill); kept as a named entry point so the
    /// serving loop reads like the paper's prefill/decode phase split.
    fn decode_step(
        &mut self,
        state: &mut KvState,
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        debug_assert_eq!(q.rows(), 1, "decode_step takes a single query row");
        self.prefill(state, q, k_new, v_new)
    }

    /// One decode step for each of `B` **independent** sequences in a single
    /// call: row `b` of `q`/`k_new`/`v_new` is sequence `b`'s query / new
    /// K / new V row and `states[b]` its resident history. Returns a `B×d`
    /// matrix whose row `b` is sequence `b`'s output.
    ///
    /// Semantically this is exactly `B` [`decode_step`](Self::decode_step)
    /// calls — every sequence keeps its own quantization scales, running
    /// statistics and causal offset, so the outputs are **bit-identical**
    /// to the sequential loop. The pipeline implementations override this
    /// to fuse the `B` per-sequence `1×L_b` GEMMs into grouped kernel
    /// launches ([`crate::gemm::par_gemm_i8_grouped`] and friends) that
    /// spread the thread pool *across* sequences — a single decode row
    /// cannot be split across workers, a batch of sequences can. This
    /// default implementation is the sequential loop itself: the
    /// equivalence oracle the batched paths are tested against.
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(self.config(), states, q, k_new, v_new);
        let d = self.config().head_dim;
        let mut out = MatF32::zeros(states.len(), d);
        for (i, st) in states.iter_mut().enumerate() {
            let o =
                self.decode_step(st, &batch_row(q, i), &batch_row(k_new, i), &batch_row(v_new, i));
            out.row_mut(i).copy_from_slice(o.row(0));
        }
        out
    }

    /// Per-stage wall clock accumulated since the last [`reset_stats`].
    fn stage_times(&self) -> &StageTimes;

    /// Op counters accumulated since the last [`reset_stats`].
    fn op_counts(&self) -> &OpCounts;

    fn reset_stats(&mut self);

    fn name(&self) -> &'static str {
        self.kind().name()
    }
}

/// Factory for a pipeline of the given kind.
pub fn build_pipeline(kind: PipelineKind, cfg: AttentionConfig) -> Box<dyn AttentionPipeline> {
    match kind {
        PipelineKind::Fp32 => Box::new(fp32::Fp32Attention::new(cfg)),
        PipelineKind::Fp16 => Box::new(fp16::Fp16Attention::new(cfg)),
        PipelineKind::QuantOnly => Box::new(quant_only::QuantOnlyAttention::new(cfg)),
        PipelineKind::IntAttention => Box::new(int_attention::IntAttention::new(cfg)),
        PipelineKind::ExaqInt2 => Box::new(exaq_pipe::ExaqAttention::new(
            cfg,
            crate::softmax::exaq::ExaqConfig::int2(),
        )),
        PipelineKind::ExaqInt3 => Box::new(exaq_pipe::ExaqAttention::new(
            cfg,
            crate::softmax::exaq::ExaqConfig::int3(),
        )),
    }
}

/// Shared shape validation for all pipelines (one-shot path).
pub(crate) fn validate_shapes(cfg: &AttentionConfig, q: &MatF32, k: &MatF32, v: &MatF32) {
    assert_eq!(q.cols(), cfg.head_dim, "Q head_dim");
    assert_eq!(k.cols(), cfg.head_dim, "K head_dim");
    assert_eq!(v.cols(), cfg.head_dim, "V head_dim");
    assert_eq!(k.rows(), cfg.seq_len, "K seq_len");
    assert_eq!(v.rows(), cfg.seq_len, "V seq_len");
    match cfg.mask {
        Mask::Causal => assert_eq!(
            q.rows(),
            cfg.seq_len,
            "causal mask requires square attention (q rows == seq_len)"
        ),
        // Chunked prefill: the block's rows must land exactly at the end of
        // the key range — `offset + m == L`.
        Mask::CausalFrom(offset) => assert_eq!(
            offset + q.rows(),
            cfg.seq_len,
            "offset-causal mask requires offset + q rows == seq_len"
        ),
        Mask::None => {}
    }
}

/// Shared shape validation for the stateful prefill/decode path.
pub(crate) fn validate_state_shapes(
    cfg: &AttentionConfig,
    st: &KvState,
    q: &MatF32,
    k: &MatF32,
    v: &MatF32,
) {
    assert_eq!(q.cols(), cfg.head_dim, "Q head_dim");
    assert_eq!(k.cols(), cfg.head_dim, "K head_dim");
    assert_eq!(v.cols(), cfg.head_dim, "V head_dim");
    assert_eq!(st.head_dim(), cfg.head_dim, "state head_dim");
    assert_eq!(
        k.rows(),
        q.rows(),
        "prefill appends one K/V row per query row (self-attention)"
    );
    assert_eq!(v.rows(), k.rows(), "K/V row count mismatch");
    assert!(q.rows() > 0, "empty query block");
}

/// Row `i` of a `B×d` stacked per-sequence matrix as its own 1-row matrix
/// (the batched decode paths slice per-sequence rows with this).
pub(crate) fn batch_row(m: &MatF32, i: usize) -> MatF32 {
    MatF32::from_vec(1, m.cols(), m.row(i).to_vec())
}

/// The `B` stacked decode rows as per-sequence 1-row `(q, k, v)` matrices.
/// The batched pipelines slice these *before* their timed Quantize stage so
/// the per-token Quantize-ns metric stays comparable with the sequential
/// path's.
pub(crate) fn batch_rows(q: &MatF32, k: &MatF32, v: &MatF32) -> Vec<(MatF32, MatF32, MatF32)> {
    (0..q.rows())
        .map(|i| (batch_row(q, i), batch_row(k, i), batch_row(v, i)))
        .collect()
}

/// Per-sequence output rescale shared by the integer pipelines' batched
/// decode: row `i` of the flat `B×d` INT32 accumulator scaled by
/// `scale_of(i)` (each sequence's running V scale over the P̂ denominator).
/// Takes a plain slice so the callers' reusable scratch accumulators (no
/// per-token `MatI32` allocation) feed it directly.
pub(crate) fn batch_output_rescale(
    acc: &[i32],
    d: usize,
    scale_of: impl Fn(usize) -> f32,
) -> MatF32 {
    debug_assert_eq!(acc.len() % d, 0);
    let mut o = MatF32::zeros(acc.len() / d, d);
    for (i, (orow, arow)) in o.as_mut_slice().chunks_mut(d).zip(acc.chunks(d)).enumerate() {
        let s = scale_of(i);
        for (ov, &av) in orow.iter_mut().zip(arow) {
            *ov = av as f32 * s;
        }
    }
    o
}

/// Shared shape validation for the batched decode path: one state and one
/// stacked row per sequence.
pub(crate) fn validate_batch_shapes(
    cfg: &AttentionConfig,
    states: &[&mut KvState],
    q: &MatF32,
    k: &MatF32,
    v: &MatF32,
) {
    let b = states.len();
    assert_eq!(q.rows(), b, "one query row per sequence");
    assert_eq!(k.rows(), b, "one new K row per sequence");
    assert_eq!(v.rows(), b, "one new V row per sequence");
    assert_eq!(q.cols(), cfg.head_dim, "Q head_dim");
    assert_eq!(k.cols(), cfg.head_dim, "K head_dim");
    assert_eq!(v.cols(), cfg.head_dim, "V head_dim");
    for st in states.iter() {
        assert_eq!(st.head_dim(), cfg.head_dim, "state head_dim");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_round_trip() {
        for k in [
            PipelineKind::Fp32,
            PipelineKind::Fp16,
            PipelineKind::QuantOnly,
            PipelineKind::IntAttention,
        ] {
            assert_eq!(PipelineKind::parse(k.name()), Some(k), "{}", k.name());
        }
        assert_eq!(PipelineKind::parse("int"), Some(PipelineKind::IntAttention));
        assert_eq!(PipelineKind::parse("bogus"), None);
    }

    #[test]
    fn config_builders() {
        let cfg = AttentionConfig::new(128, 64).causal().with_threads(4);
        assert_eq!(cfg.seq_len, 128);
        assert_eq!(cfg.mask, Mask::Causal);
        assert_eq!(cfg.pool.size(), 4);
        assert_eq!(cfg.gemm_flops(128), 2 * 2 * 128 * 128 * 64);
        let cfg = AttentionConfig::new(128, 64).causal_from(96);
        assert_eq!(cfg.mask, Mask::CausalFrom(96));
        assert_eq!(cfg.pool.size(), 1, "default pool is single-thread");
    }

    #[test]
    fn fused_decode_policy() {
        // On by default; only an explicit 0/false/off disables it.
        // The parse policy lives (and is exercised) in `crate::util::env`;
        // this checks only the snapshot wiring.
        assert_eq!(fused_decode_default(), crate::util::env::knobs().fused_decode);
        let cfg = AttentionConfig::new(8, 4).with_fused_decode(false);
        assert!(!cfg.fused_decode);
        assert!(cfg.with_fused_decode(true).fused_decode);
    }

    #[test]
    fn decode_split_and_tiled_prefill_policy() {
        // Snapshot wiring only — parse policies live in `crate::util::env`.
        assert_eq!(decode_split_default(), crate::util::env::knobs().decode_split);
        assert_eq!(tiled_prefill_default(), crate::util::env::knobs().tiled_prefill);
        let cfg = AttentionConfig::new(8, 4).with_decode_split(4);
        assert_eq!(cfg.decode_split, 4);
        assert_eq!(cfg.with_decode_split(0).decode_split, 0, "0 = auto");
        let cfg = AttentionConfig::new(8, 4).with_tiled_prefill(false);
        assert!(!cfg.tiled_prefill);
        assert!(cfg.with_tiled_prefill(true).tiled_prefill);
    }

    #[test]
    fn factory_builds_every_kind() {
        let cfg = AttentionConfig::new(16, 8);
        for k in PipelineKind::all() {
            let p = build_pipeline(k, cfg);
            assert_eq!(p.kind(), k);
        }
    }

    #[test]
    fn begin_state_matches_kind_storage() {
        let cfg = AttentionConfig::new(16, 8);
        for k in PipelineKind::all() {
            let p = build_pipeline(k, cfg);
            let st = p.begin_state();
            assert_eq!(st.len(), 0);
            assert_eq!(st.head_dim(), 8);
            let want = match k {
                PipelineKind::Fp32 => "fp32",
                PipelineKind::Fp16 => "fp16",
                _ => "int8",
            };
            assert_eq!(st.storage_name(), want, "{}", k.name());
        }
    }
}
