//! EXAQ-softmax pipeline: the integer GEMM skeleton of IntAttention with the
//! softmax stage swapped for the EXAQ operator — exactly the substitution of
//! the paper's ablation (Tables 4–7). EXAQ's dynamic statistics pass and
//! float normalization show up in the Softmax stage timing; its probability
//! output is requantized to UINT8 to keep the PV stage integer.
//!
//! Stateful paths are prefix-sharing safe: K̂/V̂ reads go through
//! `page_list()` descriptors over possibly-shared pages, appends and the
//! Δ-stat-driven re-scale fork shared pages copy-on-write, and a shared
//! prefix carries its Δ statistics with the snapshot (the running clip
//! range is part of the pinned scale state — `crate::attention::state`).

use crate::attention::state::{Int8KvState, KvState};
use crate::attention::{
    batch_output_rescale, batch_rows, counts, validate_batch_shapes, validate_shapes,
    validate_state_shapes, AttentionConfig, AttentionPipeline, PipelineKind,
};
use crate::energy::OpCounts;
use crate::gemm::{
    decode_split_spans, gemm_u8i8, gemm_u8i8_paged, par_fused_decode_exaq_spans, par_gemm_i8,
    par_gemm_i8_grouped, par_gemm_i8_paged, par_gemm_u8i8_grouped, par_tiled_prefill_exaq_pv,
    par_tiled_prefill_exaq_stats, FusedJobExaq, GroupI8, GroupU8I8, TiledPrefillExaqJob,
    TiledPrefillStatsJob, PREFILL_TILE_ROWS, ROW_BLOCK,
};
use crate::quant::quantize_i8;
use crate::softmax::exaq::{ExaqConfig, ExaqSoftmax};
use crate::softmax::index_softmax::Mask;
use crate::tensor::{MatF32, MatI32};
use crate::util::timer::{Stage, StageTimes};

pub struct ExaqAttention {
    cfg: AttentionConfig,
    softmax: ExaqSoftmax,
    times: StageTimes,
    ops: OpCounts,
    /// Reusable decode-step scratch (see `IntAttention`): flat unfused
    /// logit/prob/acc rows plus the fused path's bucketed i64 lane
    /// accumulators (one `entries × d` block per span) and QK page tiles —
    /// allocation-free once capacities reach the working shape.
    dec_logits: Vec<i32>,
    dec_probs: Vec<u8>,
    dec_acc: Vec<i32>,
    dec_facc: Vec<i64>,
    dec_tile: Vec<i32>,
}

impl ExaqAttention {
    pub fn new(cfg: AttentionConfig, exaq: ExaqConfig) -> Self {
        ExaqAttention {
            cfg,
            softmax: ExaqSoftmax::new(exaq),
            times: StageTimes::new(),
            ops: OpCounts::default(),
            dec_logits: Vec::new(),
            dec_probs: Vec::new(),
            dec_acc: Vec::new(),
            dec_facc: Vec::new(),
            dec_tile: Vec::new(),
        }
    }
}

impl AttentionPipeline for ExaqAttention {
    fn kind(&self) -> PipelineKind {
        if self.softmax.cfg.bits == 2 {
            PipelineKind::ExaqInt2
        } else {
            PipelineKind::ExaqInt3
        }
    }

    fn config(&self) -> &AttentionConfig {
        &self.cfg
    }

    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_shapes(&self.cfg, q, k, v);
        let (m, l, d) = (q.rows(), self.cfg.seq_len, self.cfg.head_dim);
        let pool = self.cfg.pool;

        let (qq, kq, vq) = self.times.measure(Stage::Quantize, || {
            (quantize_i8(q), quantize_i8(k), quantize_i8(v))
        });
        self.ops.add(&counts::quantize_qkv(m, l, d));
        let alpha = qq.scale * kq.scale / (d as f32).sqrt();

        let mut logits = MatI32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            par_gemm_i8(&qq.data, &kq.data, &mut logits, pool);
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // EXAQ softmax (dynamic clipping stats + LUT + float normalization);
        // the operator reports the nonzero-P̂ count — no re-scan.
        let (p, nnz) = self.times.measure(Stage::Softmax, || {
            let clip = self.softmax.dynamic_clip(&logits, alpha, self.cfg.mask);
            self.softmax
                .forward_with_clip_counted(&logits, alpha, self.cfg.mask, clip)
        });
        let valid = counts::valid_positions(m, l, self.cfg.mask);
        self.ops.add(&counts::exaq_softmax(valid, m as u64));

        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_u8i8(&p, &vq.data, &mut acc);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        let out_scale = vq.scale / 255.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Stateful block forward. K̂/V̂ stay resident as INT8; EXAQ's dynamic
    /// clip range comes from **running** Δ-statistics carried in the state,
    /// so a decode step merges one row's statistics instead of re-scanning
    /// the whole history (and converges to the one-shot global clip as the
    /// sequence grows).
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_state_shapes(&self.cfg, state, q, k, v);
        let (m, d) = (q.rows(), self.cfg.head_dim);
        let pool = self.cfg.pool;

        let (qq, remapped) = self.times.measure(Stage::Quantize, || {
            let remapped = state.append(k, v);
            (quantize_i8(q), remapped)
        });
        self.ops.add(&counts::quantize_qkv(m, k.rows(), d));
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let st = state.as_int8_mut();
        let l = st.len();
        let mask = Mask::CausalFrom(l - m);
        let alpha = qq.scale * st.k.scale / (d as f32).sqrt();

        if self.cfg.tiled_prefill {
            // Online-tiled EXAQ prefill: one pure-integer stats walk per row
            // (running max + exact i128 Δ-moments), the running clip/LUT
            // resolved once on the launching thread, then a gather + P̂V̂ walk
            // that replays the materialized operator's f32 ops in order — no
            // m×L score block is ever held.
            let k_pages = st.k.data.page_list();
            let v_pages = st.v.data.page_list();
            let qdata = qq.data.as_slice();
            let blocks: Vec<(usize, usize)> = (0..m)
                .step_by(ROW_BLOCK)
                .map(|r0| (r0, (r0 + ROW_BLOCK).min(m)))
                .collect();
            let mut maxes = vec![0i32; m];
            let mut moments = vec![(0i128, 0i128, 0u64); m];
            let mut tiles = vec![0i32; blocks.len() * PREFILL_TILE_ROWS];
            {
                let mut jobs: Vec<TiledPrefillStatsJob> = Vec::with_capacity(blocks.len());
                let mut mx_rest: &mut [i32] = &mut maxes;
                let mut mo_rest: &mut [(i128, i128, u64)] = &mut moments;
                let mut tile_rest: &mut [i32] = &mut tiles;
                for &(a, bb) in &blocks {
                    let (mx, mxr) = mx_rest.split_at_mut(bb - a);
                    mx_rest = mxr;
                    let (mo, mor) = mo_rest.split_at_mut(bb - a);
                    mo_rest = mor;
                    let (tl, tr) = tile_rest.split_at_mut(PREFILL_TILE_ROWS);
                    tile_rest = tr;
                    jobs.push(TiledPrefillStatsJob {
                        q: &qdata[a * d..bb * d],
                        row0: a,
                        mask,
                        l,
                        kp: &k_pages,
                        maxes: mx,
                        moments: mo,
                        tile: tl,
                    });
                }
                self.times.measure(Stage::QkGemm, || {
                    par_tiled_prefill_exaq_stats(&mut jobs, pool);
                });
            }
            self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

            // Fold the exact integer moments into `delta_stats` units in row
            // order, merge into the running accumulator, clip from running σ.
            let (lut, clip_int) = self.times.measure(Stage::Softmax, || {
                let af = alpha as f64;
                let (mut sum, mut sumsq, mut n) = (0f64, 0f64, 0u64);
                for &(ds, dq, nn) in &moments {
                    sum += ds as f64 * af;
                    sumsq += dq as f64 * (af * af);
                    n += nn;
                }
                st.exaq.merge(sum, sumsq, n);
                let clip = self.softmax.clip_from_sigma(st.exaq.sigma());
                let lut = self.softmax.lut_f32(clip);
                let clip_int = (clip.max(1e-3) / alpha).max(1.0);
                (lut, clip_int)
            });
            let valid = counts::valid_positions(m, l, mask);
            self.ops.add(&counts::exaq_softmax(valid, m as u64));

            let mut out_i32 = vec![0i32; m * d];
            let nnz: u64;
            {
                let mut jobs: Vec<TiledPrefillExaqJob> = Vec::with_capacity(blocks.len());
                let mut out_rest: &mut [i32] = &mut out_i32;
                let mut tile_rest: &mut [i32] = &mut tiles;
                for &(a, bb) in &blocks {
                    let (orow, orest) = out_rest.split_at_mut((bb - a) * d);
                    out_rest = orest;
                    let (tl, tr) = tile_rest.split_at_mut(PREFILL_TILE_ROWS);
                    tile_rest = tr;
                    jobs.push(TiledPrefillExaqJob {
                        q: &qdata[a * d..bb * d],
                        row0: a,
                        mask,
                        l,
                        kp: &k_pages,
                        vp: &v_pages,
                        maxes: &maxes[a..bb],
                        lut: &lut,
                        clip_int,
                        out: orow,
                        tile: tl,
                        nnz: 0,
                    });
                }
                self.times.measure(Stage::QkGemm, || {
                    par_tiled_prefill_exaq_pv(&mut jobs, pool);
                });
                nnz = jobs.iter().map(|j| j.nnz).sum();
            }
            for _ in 0..2 {
                self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));
            }
            self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

            let out_scale = st.v.scale / 255.0;
            let o = self.times.measure(Stage::Output, || {
                let mut o = MatF32::zeros(m, d);
                for (ov, &av) in o.as_mut_slice().iter_mut().zip(&out_i32) {
                    *ov = av as f32 * out_scale;
                }
                o
            });
            self.ops.add(&counts::output_rescale(m, d));
            return o;
        }

        let mut logits = MatI32::zeros(m, l);
        {
            let k_pages = st.k.data.page_list();
            self.times.measure(Stage::QkGemm, || {
                par_gemm_i8_paged(qq.data.as_slice(), &k_pages, logits.as_mut_slice(), m, l, d, pool);
            });
        }
        self.ops.add(&counts::qk_gemm(m, l, d, 1, 4));

        // EXAQ softmax: merge this block's Δ stats into the running
        // accumulator, clip from the running σ.
        let (p, nnz) = self.times.measure(Stage::Softmax, || {
            let (sum, sumsq, n) = ExaqSoftmax::delta_stats(&logits, alpha, mask);
            st.exaq.merge(sum, sumsq, n);
            let clip = self.softmax.clip_from_sigma(st.exaq.sigma());
            self.softmax.forward_with_clip_counted(&logits, alpha, mask, clip)
        });
        let valid = counts::valid_positions(m, l, mask);
        self.ops.add(&counts::exaq_softmax(valid, m as u64));

        let v_pages = st.v.data.page_list();
        let mut acc = MatI32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            gemm_u8i8_paged(p.as_slice(), &v_pages, acc.as_mut_slice(), m, l, d);
        });
        self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));

        let out_scale = st.v.scale / 255.0;
        let o = self
            .times
            .measure(Stage::Output, || acc.map(|x| x as f32 * out_scale));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Single-sequence decode routes through [`Self::decode_step_batch`]
    /// with one lane — one code path (fused or unfused) and shared scratch.
    fn decode_step(
        &mut self,
        state: &mut KvState,
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        debug_assert_eq!(q.rows(), 1, "decode_step takes a single query row");
        self.decode_step_batch(&mut [state], q, k_new, v_new)
    }

    /// Batched decode: grouped integer GEMMs with per-sequence EXAQ
    /// statistics — each sequence merges its own Δ stats into its own
    /// running accumulator and clips from its own σ, so the result is
    /// bit-identical to single-lane [`AttentionPipeline::decode_step`].
    ///
    /// With `cfg.fused_decode` set, each sequence runs the two-phase fused
    /// walk — `Q̂K̂ᵀ` tiles through the max fold, then a zipped re-walk
    /// bucketing `V̂` lanes by LUT index into pure-i64 accumulators — split
    /// into `cfg.decode_split` page spans merged exactly (byte-identical
    /// for any split). The dynamic clip comes from the *pre-step* running σ
    /// (the fused walk cannot see this step's Δ distribution before
    /// gathering) and the step's exact Δ-moments are merged after the walk
    /// — stale by exactly one token relative to the unfused oracle, which
    /// converges as L grows. The fused output also skips the ×255 `P̂`
    /// requantization entirely (`counts::exaq_softmax_fused`).
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(&self.cfg, states, q, k_new, v_new);
        let b = states.len();
        let d = self.cfg.head_dim;
        if b == 0 {
            return MatF32::zeros(0, d);
        }
        let pool = self.cfg.pool;
        let sqrt_d = (d as f32).sqrt();

        // (1) per-sequence append + query quantization.
        let rows = batch_rows(q, k_new, v_new);
        let (qqs, remapped) = self.times.measure(Stage::Quantize, || {
            let mut remapped = 0usize;
            let mut qqs = Vec::with_capacity(b);
            for (st, (qr, kr, vr)) in states.iter_mut().zip(&rows) {
                remapped += st.append(kr, vr);
                qqs.push(quantize_i8(qr));
            }
            (qqs, remapped)
        });
        for _ in 0..b {
            self.ops.add(&counts::quantize_qkv(1, 1, d));
        }
        if remapped > 0 {
            self.ops.add(&counts::kv_rescale(remapped as u64));
        }

        let ls: Vec<usize> = states.iter().map(|st| st.len()).collect();

        if self.cfg.fused_decode {
            // Fused flash-decode, span-parallel: pre-step clips/LUTs, each
            // sequence's page list split into contiguous spans walked
            // two-phase (max fold, then bucketed Ê·V̂ gather into pure-i64
            // `entries × d` lane accumulators), merged exactly — the LUT
            // floats touch the result once, in the final per-lane combine.
            let stats: Vec<(f64, f64, u64)>;
            let o;
            {
                let ints: Vec<&Int8KvState> = states.iter().map(|st| st.as_int8()).collect();
                let k_pages: Vec<Vec<&[i8]>> =
                    ints.iter().map(|s| s.k.data.page_list()).collect();
                let v_pages: Vec<Vec<&[i8]>> =
                    ints.iter().map(|s| s.v.data.page_list()).collect();
                let alphas: Vec<f32> = qqs
                    .iter()
                    .zip(&ints)
                    .map(|(qq, s)| qq.scale * s.k.scale / sqrt_d)
                    .collect();
                let clips: Vec<f32> = ints
                    .iter()
                    .map(|s| self.softmax.clip_from_sigma(s.exaq.sigma()))
                    .collect();
                let luts: Vec<Vec<f32>> =
                    clips.iter().map(|&c| self.softmax.lut_f32(c)).collect();

                let split = self.cfg.decode_split;
                let spans: Vec<usize> = k_pages
                    .iter()
                    .map(|kp| decode_split_spans(split, kp.len(), pool.size(), b))
                    .collect();
                let total_spans: usize = spans.iter().sum();
                let mut cuts: Vec<(usize, usize, usize)> = Vec::with_capacity(total_spans);
                for (i, (&n, kp)) in spans.iter().zip(&k_pages).enumerate() {
                    let (base, extra) = (kp.len() / n, kp.len() % n);
                    let mut at = 0;
                    for s in 0..n {
                        let take = base + usize::from(s < extra);
                        cuts.push((i, at, at + take));
                        at += take;
                    }
                }
                let tile_rows: Vec<usize> = cuts
                    .iter()
                    .map(|&(i, a, e)| {
                        k_pages[i][a..e].iter().map(|p| p.len() / d).max().unwrap_or(0)
                    })
                    .collect();
                let entries = self.softmax.entries();
                let mut facc = std::mem::take(&mut self.dec_facc);
                let mut tile = std::mem::take(&mut self.dec_tile);
                facc.clear();
                facc.resize(total_spans * entries * d, 0);
                tile.clear();
                tile.resize(tile_rows.iter().sum(), 0);

                let softmax = &self.softmax;
                let mut jobs: Vec<FusedJobExaq> = Vec::with_capacity(total_spans);
                let mut acc_rest: &mut [i64] = &mut facc;
                let mut tile_rest: &mut [i32] = &mut tile;
                for (ci, &(i, a, e)) in cuts.iter().enumerate() {
                    let (acc, ar) = acc_rest.split_at_mut(entries * d);
                    acc_rest = ar;
                    let (tl, tr) = tile_rest.split_at_mut(tile_rows[ci]);
                    tile_rest = tr;
                    jobs.push(FusedJobExaq {
                        q: qqs[i].data.as_slice(),
                        kp: &k_pages[i][a..e],
                        vp: &v_pages[i][a..e],
                        row: softmax.online_begin(alphas[i], clips[i]),
                        lut: &luts[i],
                        acc,
                        tile: tl,
                    });
                }

                self.times.measure(Stage::QkGemm, || {
                    par_fused_decode_exaq_spans(&mut jobs, &spans, pool);
                });
                // Each sequence's merged result lives in its first span job;
                // the K̂ pages are walked twice (max + gather), so two QK
                // walks are billed.
                let mut firsts: Vec<usize> = Vec::with_capacity(b);
                let mut at = 0;
                for &n in &spans {
                    firsts.push(at);
                    at += n;
                }
                for (&f, &l) in firsts.iter().zip(&ls) {
                    self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
                    self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
                    self.ops.add(&counts::exaq_softmax_fused(l as u64, 1));
                    self.ops.add(&counts::pv_gemm(jobs[f].row.nnz(), l, d, 1, 4));
                }

                // Final per-lane combine `Σ_t lut[t]·acc[t] / Σe · s_V` — no
                // ×255 requantize, no /255 restore: the LUT floats meet the
                // integer lane sums only here.
                o = self.times.measure(Stage::Output, || {
                    let mut out = MatF32::zeros(b, d);
                    for ((&f, s), orow) in
                        firsts.iter().zip(&ints).zip(out.as_mut_slice().chunks_mut(d))
                    {
                        let job = &jobs[f];
                        let inv = 1.0 / job.row.fsum(job.lut);
                        let out_scale = s.v.scale;
                        let zb = job.row.zero_bucket();
                        let cnts = job.row.counts();
                        for (lane, ov) in orow.iter_mut().enumerate() {
                            let mut x = 0f32;
                            for t in 0..zb {
                                if cnts[t] != 0 {
                                    x += job.lut[t] * (job.acc[t * d + lane] as f32);
                                }
                            }
                            *ov = x * inv * out_scale;
                        }
                    }
                    out
                });
                for _ in 0..b {
                    self.ops.add(&counts::output_rescale(1, d));
                }
                stats = firsts
                    .iter()
                    .zip(&alphas)
                    .map(|(&f, &a)| jobs[f].row.stats(a))
                    .collect();
                drop(jobs);
                self.dec_facc = facc;
                self.dec_tile = tile;
            }
            // Merge the walk's exact Δ-moments into each running accumulator
            // (the *next* step's clip sees them — stale-by-one contract).
            for (st, (sum, sumsq, n)) in states.iter_mut().zip(stats) {
                st.as_int8_mut().exaq.merge(sum, sumsq, n);
            }
            return o;
        }

        // ------------------------- unfused oracle -------------------------
        // (2) one grouped Q̂·K̂ᵀ launch into one flat reusable logit buffer.
        let total: usize = ls.iter().sum();
        let mut logits = std::mem::take(&mut self.dec_logits);
        let mut probs = std::mem::take(&mut self.dec_probs);
        let mut acc = std::mem::take(&mut self.dec_acc);
        logits.clear();
        logits.resize(total, 0);
        probs.clear();
        probs.resize(total, 0);
        acc.clear();
        acc.resize(b * d, 0);
        {
            let ints: Vec<&Int8KvState> = states.iter().map(|st| st.as_int8()).collect();
            let k_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.k.data.page_list()).collect();
            self.times.measure(Stage::QkGemm, || {
                let mut groups: Vec<GroupI8> = Vec::with_capacity(b);
                let mut rest: &mut [i32] = &mut logits;
                for (qq, (kp, &l)) in qqs.iter().zip(k_pages.iter().zip(&ls)) {
                    let (lg, r) = rest.split_at_mut(l);
                    rest = r;
                    groups.push(GroupI8 { a: qq.data.as_slice(), b: kp, out: lg });
                }
                par_gemm_i8_grouped(&mut groups, d, pool);
            });
            for &l in &ls {
                self.ops.add(&counts::qk_gemm(1, l, d, 1, 4));
            }
        }

        // (3) per-sequence EXAQ softmax over the flat spans: merge each
        // sequence's Δ stats into its own running accumulator, clip from its
        // own running σ, normalize into the reusable P̂ row.
        let nnzs: Vec<u64> = self.times.measure(Stage::Softmax, || {
            let softmax = &self.softmax;
            let mut nnzs = Vec::with_capacity(b);
            let mut lg_rest: &[i32] = &logits;
            let mut pr_rest: &mut [u8] = &mut probs;
            for (st, (qq, &l)) in states.iter_mut().zip(qqs.iter().zip(&ls)) {
                let (lg, lr) = lg_rest.split_at(l);
                lg_rest = lr;
                let (pr, prr) = pr_rest.split_at_mut(l);
                pr_rest = prr;
                let s = st.as_int8_mut();
                let alpha = qq.scale * s.k.scale / sqrt_d;
                let (sum, sumsq, n) = ExaqSoftmax::delta_stats_row(lg, alpha);
                s.exaq.merge(sum, sumsq, n);
                let clip = softmax.clip_from_sigma(s.exaq.sigma());
                let lut = softmax.lut_f32(clip);
                nnzs.push(softmax.forward_row_with_clip(lg, alpha, clip, &lut, pr));
            }
            nnzs
        });
        for &l in &ls {
            self.ops.add(&counts::exaq_softmax(l as u64, 1));
        }

        // (4) one grouped P̂·V̂ launch over the B resident V̂ page lists.
        let ints: Vec<&Int8KvState> = states.iter().map(|st| st.as_int8()).collect();
        let v_pages: Vec<Vec<&[i8]>> = ints.iter().map(|s| s.v.data.page_list()).collect();
        self.times.measure(Stage::PvGemm, || {
            let mut groups: Vec<GroupU8I8> = Vec::with_capacity(b);
            let mut pr_rest: &[u8] = &probs;
            for ((vp, &l), out) in v_pages.iter().zip(&ls).zip(acc.chunks_mut(d)) {
                let (pr, r) = pr_rest.split_at(l);
                pr_rest = r;
                groups.push(GroupU8I8 { a: pr, b: vp, out });
            }
            par_gemm_u8i8_grouped(&mut groups, d, pool);
        });
        for (&nnz, &l) in nnzs.iter().zip(&ls) {
            self.ops.add(&counts::pv_gemm(nnz, l, d, 1, 4));
        }

        // (5) per-sequence output rescale with each state's running V scale.
        let o = self
            .times
            .measure(Stage::Output, || {
                batch_output_rescale(&acc, d, |i| ints[i].v.scale / 255.0)
            });
        for _ in 0..b {
            self.ops.add(&counts::output_rescale(1, d));
        }
        self.dec_logits = logits;
        self.dec_probs = probs;
        self.dec_acc = acc;
        o
    }

    fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fp32::reference_attention;
    use crate::attention::int_attention::IntAttention;
    use crate::softmax::index_softmax::Mask;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn int3_tracks_reference_reasonably() {
        let mut rng = Pcg64::seed_from_u64(1);
        let cfg = AttentionConfig::new(64, 32);
        let q = rand_mat(&mut rng, 32, 32);
        let k = rand_mat(&mut rng, 64, 32);
        let v = rand_mat(&mut rng, 64, 32);
        let got = ExaqAttention::new(cfg, ExaqConfig::int3()).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::None);
        let cos = crate::util::stats::cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos > 0.97, "cos={cos}");
    }

    #[test]
    fn fidelity_order_int2_lt_int3_lt_intattention() {
        // The Table 5–7 ordering at pipeline level, averaged across trials.
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = AttentionConfig::new(128, 32);
        let mut e2 = 0.0;
        let mut e3 = 0.0;
        let mut ei = 0.0;
        for _ in 0..8 {
            let q = rand_mat(&mut rng, 64, 32);
            let k = rand_mat(&mut rng, 128, 32);
            let v = rand_mat(&mut rng, 128, 32);
            let want = reference_attention(&q, &k, &v, Mask::None);
            let g2 = ExaqAttention::new(cfg, ExaqConfig::int2()).forward(&q, &k, &v);
            let g3 = ExaqAttention::new(cfg, ExaqConfig::int3()).forward(&q, &k, &v);
            let gi = IntAttention::new(cfg).forward(&q, &k, &v);
            e2 += crate::util::stats::rmse(want.as_slice(), g2.as_slice());
            e3 += crate::util::stats::rmse(want.as_slice(), g3.as_slice());
            ei += crate::util::stats::rmse(want.as_slice(), gi.as_slice());
        }
        assert!(e3 < e2, "INT3 rmse {e3} !< INT2 rmse {e2}");
        assert!(ei < e3, "IntAttention rmse {ei} !< INT3 rmse {e3}");
    }

    #[test]
    fn kind_reflects_bits() {
        let cfg = AttentionConfig::new(8, 4);
        assert_eq!(
            ExaqAttention::new(cfg, ExaqConfig::int2()).kind(),
            PipelineKind::ExaqInt2
        );
        assert_eq!(
            ExaqAttention::new(cfg, ExaqConfig::int3()).kind(),
            PipelineKind::ExaqInt3
        );
    }
}
