//! FP16 baseline attention: operands stored as binary16, arithmetic in f32
//! (software-f16 substitution, DESIGN.md §2). The pipeline pays real
//! conversion costs at each boundary — matching the dataflow, if not the ALU
//! economics, of a native FP16 edge path. Energy accounting prices the GEMMs
//! at fp16-MAC cost, which is where the real-hardware advantage lives.
//!
//! Stateful paths read resident K/V through `page_list()` descriptors, so
//! they tolerate pages shared copy-on-write across sequences; the append
//! path forks a shared tail page before writing
//! (see `crate::attention::state`).

use crate::attention::state::{F16KvState, KvState};
use crate::attention::{
    batch_row, counts, validate_batch_shapes, validate_shapes, validate_state_shapes,
    AttentionConfig, AttentionPipeline, PipelineKind,
};
use crate::energy::OpCounts;
use crate::gemm::{
    gemm_f16, gemm_f16_notrans_paged, gemm_f16_paged, par_gemm_f16_grouped,
    par_gemm_f16_notrans_grouped, GroupF16,
};
use crate::softmax::float_softmax::softmax_rows_f16;
use crate::softmax::index_softmax::Mask;
use crate::tensor::MatF32;
use crate::util::f16::{encode_slice, F16};
use crate::util::timer::{Stage, StageTimes};

pub struct Fp16Attention {
    cfg: AttentionConfig,
    times: StageTimes,
    ops: OpCounts,
}

impl Fp16Attention {
    pub fn new(cfg: AttentionConfig) -> Self {
        Fp16Attention { cfg, times: StageTimes::new(), ops: OpCounts::default() }
    }
}

impl AttentionPipeline for Fp16Attention {
    fn kind(&self) -> PipelineKind {
        PipelineKind::Fp16
    }

    fn config(&self) -> &AttentionConfig {
        &self.cfg
    }

    fn forward(&mut self, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_shapes(&self.cfg, q, k, v);
        let (m, l, d) = (q.rows(), self.cfg.seq_len, self.cfg.head_dim);
        let scale = 1.0 / (d as f32).sqrt();

        // Encode inputs to f16 storage.
        let (qh, kh) = self.times.measure(Stage::Quantize, || {
            (encode_slice(q.as_slice()), encode_slice(k.as_slice()))
        });
        self.ops.add(&counts::encode_qkv_f16(m, l, d));

        // QKᵀ in f16 storage.
        let mut a = MatF32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            gemm_f16(&qh, &kh, m, l, d, a.as_mut_slice());
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 2, 2));

        // Scale (kept in f32 — the f16 rounding happens after the stable
        // max subtraction inside softmax_rows_f16, matching real FP16
        // kernels and keeping huge logits finite) + f16-precision softmax.
        self.times.measure(Stage::Softmax, || {
            for x in a.as_mut_slice() {
                *x *= scale;
            }
            softmax_rows_f16(&mut a, self.cfg.mask);
        });
        let valid = counts::valid_positions(m, l, self.cfg.mask);
        self.ops.add(&counts::fp32_softmax(valid, m as u64)); // same op mix, f16 units

        // PV in f16 storage: encode P, multiply against V-f16.
        let mut o = MatF32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            let ph: Vec<F16> = encode_slice(a.as_slice());
            // V must be transposed for gemm_f16's bt layout.
            let vt = crate::tensor::MatF32::from_vec(l, d, v.as_slice().to_vec()).transpose();
            let vth = encode_slice(vt.as_slice());
            gemm_f16(&ph, &vth, m, d, l, o.as_mut_slice());
        });
        self.ops.add(&counts::pv_gemm(valid, l, d, 2, 2));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Stateful block forward over binary16-resident K/V rows: new rows are
    /// encoded to f16 once on append; the PV aggregation streams the
    /// resident `L×d` V rows without the per-step transpose the one-shot
    /// path uses.
    fn prefill(&mut self, state: &mut KvState, q: &MatF32, k: &MatF32, v: &MatF32) -> MatF32 {
        validate_state_shapes(&self.cfg, state, q, k, v);
        let (m, d) = (q.rows(), self.cfg.head_dim);
        let scale = 1.0 / (d as f32).sqrt();

        // Encode the query block + the new K/V rows into f16 storage.
        let qh = self.times.measure(Stage::Quantize, || {
            state.append(k, v);
            encode_slice(q.as_slice())
        });
        self.ops.add(&counts::encode_qkv_f16(m, k.rows(), d));

        let st = state.as_f16();
        let l = st.len();
        let mask = Mask::CausalFrom(l - m);

        // QKᵀ in f16 storage against the resident key pages.
        let k_pages = st.k.page_list();
        let mut a = MatF32::zeros(m, l);
        self.times.measure(Stage::QkGemm, || {
            gemm_f16_paged(&qh, &k_pages, m, l, d, a.as_mut_slice());
        });
        self.ops.add(&counts::qk_gemm(m, l, d, 2, 2));

        // Scale + f16-precision softmax over the offset-causal window.
        self.times.measure(Stage::Softmax, || {
            for x in a.as_mut_slice() {
                *x *= scale;
            }
            softmax_rows_f16(&mut a, mask);
        });
        let valid = counts::valid_positions(m, l, mask);
        self.ops.add(&counts::fp32_softmax(valid, m as u64)); // same op mix, f16 units

        // PV in f16 storage, V pages in natural row layout (no transpose
        // copy, no flattening copy).
        let v_pages = st.v.page_list();
        let mut o = MatF32::zeros(m, d);
        self.times.measure(Stage::PvGemm, || {
            let ph: Vec<F16> = encode_slice(a.as_slice());
            gemm_f16_notrans_paged(&ph, &v_pages, o.as_mut_slice(), m, l, d);
        });
        self.ops.add(&counts::pv_gemm(valid, l, d, 2, 2));
        self.ops.add(&counts::output_rescale(m, d));
        o
    }

    /// Batched decode: per-sequence f16 encodes and softmaxes, one grouped
    /// launch per GEMM side — bit-identical per sequence to the sequential
    /// [`AttentionPipeline::decode_step`] (each group runs the very same
    /// `gemm_f16`/`gemm_f16_notrans` call the sequential path would).
    fn decode_step_batch(
        &mut self,
        states: &mut [&mut KvState],
        q: &MatF32,
        k_new: &MatF32,
        v_new: &MatF32,
    ) -> MatF32 {
        validate_batch_shapes(&self.cfg, states, q, k_new, v_new);
        let b = states.len();
        let d = self.cfg.head_dim;
        if b == 0 {
            return MatF32::zeros(0, d);
        }
        let pool = self.cfg.pool;
        let scale = 1.0 / (d as f32).sqrt();

        // (1) per-sequence append + query-row encode to f16 storage. Row
        // slicing happens outside the timer so the Quantize-ns metric stays
        // comparable with the sequential path's.
        let rows: Vec<(MatF32, MatF32)> = (0..b)
            .map(|i| (batch_row(k_new, i), batch_row(v_new, i)))
            .collect();
        let qhs: Vec<Vec<F16>> = self.times.measure(Stage::Quantize, || {
            let mut qhs = Vec::with_capacity(b);
            for ((i, st), (kr, vr)) in states.iter_mut().enumerate().zip(&rows) {
                st.append(kr, vr);
                qhs.push(encode_slice(q.row(i)));
            }
            qhs
        });
        for _ in 0..b {
            self.ops.add(&counts::encode_qkv_f16(1, 1, d));
        }

        let hs: Vec<&F16KvState> = states.iter().map(|st| st.as_f16()).collect();

        // (2) one grouped QKᵀ launch in f16 storage over the page lists.
        let k_pages: Vec<Vec<&[F16]>> = hs.iter().map(|s| s.k.page_list()).collect();
        let mut a_rows: Vec<MatF32> = hs.iter().map(|s| MatF32::zeros(1, s.len())).collect();
        self.times.measure(Stage::QkGemm, || {
            let mut groups: Vec<GroupF16> = qhs
                .iter()
                .zip(&k_pages)
                .zip(a_rows.iter_mut())
                .map(|((qh, kp), ar)| GroupF16 {
                    a: qh.as_slice(),
                    b: kp.as_slice(),
                    out: ar.as_mut_slice(),
                })
                .collect();
            par_gemm_f16_grouped(&mut groups, d, pool);
        });
        for s in &hs {
            self.ops.add(&counts::qk_gemm(1, s.len(), d, 2, 2));
        }

        // (3) per-sequence scale + f16-precision softmax.
        self.times.measure(Stage::Softmax, || {
            for (ar, s) in a_rows.iter_mut().zip(&hs) {
                for x in ar.as_mut_slice() {
                    *x *= scale;
                }
                softmax_rows_f16(ar, Mask::CausalFrom(s.len() - 1));
            }
        });
        for s in &hs {
            self.ops.add(&counts::fp32_softmax(s.len() as u64, 1)); // same op mix, f16 units
        }

        // (4) encode each P row + one grouped PV launch over the resident
        // V page lists.
        let v_pages: Vec<Vec<&[F16]>> = hs.iter().map(|s| s.v.page_list()).collect();
        let mut o = MatF32::zeros(b, d);
        self.times.measure(Stage::PvGemm, || {
            let phs: Vec<Vec<F16>> = a_rows.iter().map(|ar| encode_slice(ar.as_slice())).collect();
            let mut groups: Vec<GroupF16> = Vec::with_capacity(b);
            for ((ph, vp), orow) in phs.iter().zip(&v_pages).zip(o.as_mut_slice().chunks_mut(d)) {
                groups.push(GroupF16 { a: ph.as_slice(), b: vp.as_slice(), out: orow });
            }
            par_gemm_f16_notrans_grouped(&mut groups, d, pool);
        });
        for s in &hs {
            self.ops.add(&counts::pv_gemm(s.len() as u64, s.len(), d, 2, 2));
            self.ops.add(&counts::output_rescale(1, d));
        }
        o
    }

    fn stage_times(&self) -> &StageTimes {
        &self.times
    }

    fn op_counts(&self) -> &OpCounts {
        &self.ops
    }

    fn reset_stats(&mut self) {
        self.times.reset();
        self.ops = OpCounts::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::fp32::reference_attention;
    use crate::softmax::index_softmax::Mask;
    use crate::util::prng::Pcg64;

    fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
        MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn close_to_fp32_reference() {
        let mut rng = Pcg64::seed_from_u64(1);
        let cfg = AttentionConfig::new(32, 16);
        let q = rand_mat(&mut rng, 16, 16);
        let k = rand_mat(&mut rng, 32, 16);
        let v = rand_mat(&mut rng, 32, 16);
        let mut pipe = Fp16Attention::new(cfg);
        let got = pipe.forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::None);
        // f16 has ~3 decimal digits; attention outputs are O(1).
        assert!(got.allclose(&want, 5e-3, 2e-2), "fp16 deviates too much");
    }

    #[test]
    fn causal_supported() {
        let mut rng = Pcg64::seed_from_u64(2);
        let cfg = AttentionConfig::new(16, 8).causal();
        let q = rand_mat(&mut rng, 16, 8);
        let k = rand_mat(&mut rng, 16, 8);
        let v = rand_mat(&mut rng, 16, 8);
        let got = Fp16Attention::new(cfg).forward(&q, &k, &v);
        let want = reference_attention(&q, &k, &v, Mask::Causal);
        assert!(got.allclose(&want, 5e-3, 2e-2));
    }

    #[test]
    fn counts_use_fp16_macs() {
        let mut rng = Pcg64::seed_from_u64(3);
        let cfg = AttentionConfig::new(16, 8);
        let q = rand_mat(&mut rng, 16, 8);
        let k = rand_mat(&mut rng, 16, 8);
        let v = rand_mat(&mut rng, 16, 8);
        let mut pipe = Fp16Attention::new(cfg);
        let _ = pipe.forward(&q, &k, &v);
        assert_eq!(pipe.op_counts().fp16_mac, 2 * 16 * 16 * 8);
        assert_eq!(pipe.op_counts().fp32_mac, 0);
        assert!(pipe.op_counts().dtype_conv > 0);
    }
}
