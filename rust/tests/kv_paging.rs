//! Paged-KV residency properties, driven by the in-crate miniature proptest
//! harness (`util/proptest.rs`; failing seeds are reported for exact
//! reproduction).
//!
//! The core contract under test: **paging is pure layout**. A KV state
//! paged at 1, 2 or 64 rows/page must behave byte-identically to a
//! one-big-page state (the pre-paging contiguous layout) under arbitrary
//! interleavings of multi-row appends (prefill chunks), magnitude ramps
//! (INT8 re-scale remaps across page boundaries) and single-row decode
//! steps — for every pipeline kind, including the float ones.

use intattention::attention::{
    build_pipeline, page_pool_stats, AttentionConfig, KvState, PipelineKind,
};
use intattention::tensor::MatF32;
use intattention::util::proptest::{check, Config};
use intattention::util::prng::Pcg64;
use std::sync::Mutex;

/// Tests in this binary that assert *exact* page-pool counter deltas take
/// this lock: the pools are process-wide, so only serialization (within
/// this test process — each integration-test file is its own process)
/// makes `outstanding()` comparisons sound.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn pool_lock() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, gain: f32) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal() * gain).collect())
}

// Last entry exceeds any row count a schedule below reaches (≤ ~40 rows),
// so that state keeps one page per side — the pre-paging contiguous layout.
const PAGE_SIZES: [usize; 4] = [1, 2, 64, 256];

#[test]
fn prop_paged_states_bit_identical_across_interleavings() {
    let _g = pool_lock();
    check(
        "paged == contiguous under random append/rescale/decode schedules",
        // Miri runs the same generator/oracle logic; a handful of cases
        // keeps the UB-checking pass tractable (CI runs this under Miri).
        Config::cases(if cfg!(miri) { 3 } else { 24 }),
        |rng| {
            let kind = PipelineKind::all()[rng.below(6) as usize];
            let d = 4 + rng.below(13) as usize; // 4..=16
            let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
            let mut states: Vec<KvState> = PAGE_SIZES
                .iter()
                .map(|&p| KvState::with_page_rows(kind, d, p))
                .collect();
            // Random schedule of prefill blocks; occasional magnitude jumps
            // force the INT8 running-scale remap mid-history.
            let blocks = 2 + rng.below(5) as usize;
            for _ in 0..blocks {
                let rows = 1 + rng.below(5) as usize;
                let gain = match rng.below(3) {
                    0 => 0.5,
                    1 => 1.0,
                    _ => 2.0 + rng.below(5) as f32, // grows amax → rescale
                };
                let q = rand_mat(rng, rows, d, 1.0);
                let k = rand_mat(rng, rows, d, gain);
                let v = rand_mat(rng, rows, d, gain);
                let mut outs: Vec<Vec<f32>> = Vec::with_capacity(states.len());
                for st in states.iter_mut() {
                    outs.push(pipe.prefill(st, &q, &k, &v).as_slice().to_vec());
                }
                for (o, &p) in outs.iter().zip(&PAGE_SIZES) {
                    assert_eq!(
                        o, &outs[3],
                        "{} prefill at page size {p} diverged from contiguous",
                        kind.name()
                    );
                }
            }
            // Decode steps on top of the shared history.
            for _ in 0..3 {
                let q = rand_mat(rng, 1, d, 1.0);
                let k = rand_mat(rng, 1, d, 1.0);
                let v = rand_mat(rng, 1, d, 1.0);
                let mut outs: Vec<Vec<f32>> = Vec::with_capacity(states.len());
                for st in states.iter_mut() {
                    outs.push(pipe.decode_step(st, &q, &k, &v).as_slice().to_vec());
                }
                for (o, &p) in outs.iter().zip(&PAGE_SIZES) {
                    assert_eq!(
                        o, &outs[3],
                        "{} decode at page size {p} diverged from contiguous",
                        kind.name()
                    );
                }
            }
            // Structural invariants: same logical content, geometry-exact
            // accounting.
            let len = states[3].len();
            for (st, &p) in states.iter().zip(&PAGE_SIZES) {
                assert_eq!(st.len(), len);
                assert_eq!(st.rows_stored(), 2 * len);
                // ceil-rounded per side: 2 sides × ⌈len/p⌉ pages.
                assert_eq!(st.pages(), 2 * len.div_ceil(p), "page size {p}");
                assert!(st.capacity_rows() >= st.rows_stored());
            }
        },
    );
}

#[test]
fn dropped_state_pages_return_to_the_pool() {
    // Build and drop a paged state, then build another with the same
    // geometry: the pool must hand pages out of its free list (the
    // recycling that lets a retired request's memory serve the next one).
    let d = 9; // unusual head_dim → page capacities other tests don't use
    let mk = |rng: &mut Pcg64| {
        let mut st = KvState::with_page_rows(PipelineKind::IntAttention, d, 3);
        let rows = rand_mat(rng, 10, d, 1.0);
        st.append(&rows, &rows);
        assert_eq!(st.pages(), 2 * 4); // ⌈10/3⌉ per side
        st
    };
    let _g = pool_lock();
    let mut rng = Pcg64::seed_from_u64(7);
    let recycled_before = page_pool_stats().recycled;
    let st = mk(&mut rng);
    drop(st);
    let st2 = mk(&mut rng);
    let recycled_after = page_pool_stats().recycled;
    assert!(
        recycled_after > recycled_before,
        "rebuilding the same geometry after a drop must recycle pages \
         ({recycled_before} → {recycled_after})"
    );
    drop(st2);
}

#[test]
fn cloned_state_is_independent_and_equal() {
    // KvCache snapshots (tests, speculative schedulers) rely on clone
    // independence: equal content, and no observable aliasing — clones now
    // share pages copy-on-write, so independence comes from every mutation
    // path forking shared pages before writing.
    let _g = pool_lock();
    let mut rng = Pcg64::seed_from_u64(11);
    for kind in PipelineKind::all() {
        let d = 8;
        let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
        let mut st = KvState::with_page_rows(kind, d, 2);
        let block = rand_mat(&mut rng, 5, d, 1.0);
        let _ = pipe.prefill(&mut st, &block, &block, &block);
        let mut cl = st.clone();
        assert_eq!(cl.len(), st.len());
        assert_eq!(cl.bytes(), st.bytes());
        assert_eq!(cl.pages(), st.pages());
        // Decoding on the clone must match decoding on the original...
        let q = rand_mat(&mut rng, 1, d, 1.0);
        let k = rand_mat(&mut rng, 1, d, 1.0);
        let v = rand_mat(&mut rng, 1, d, 1.0);
        let a = pipe.decode_step(&mut st, &q, &k, &v);
        let b = pipe.decode_step(&mut cl, &q, &k, &v);
        assert_eq!(a.as_slice(), b.as_slice(), "{}", kind.name());
        // ...and never aliases its pages.
        assert_eq!(st.len(), cl.len());
    }
}

#[test]
fn prop_shared_prefix_cow_never_leaks_and_matches_unshared_oracle() {
    // The prefix-sharing contract under adversarial interleavings: a donor
    // computes a prefix, a snapshot shares it, several adopters ride the
    // shared pages through divergent appends (including magnitude ramps
    // that fire the INT8 re-scale remap — which must fork, not rewrite,
    // shared pages) while the donor keeps diverging and sharers retire in
    // random order. Every adopter must match its own unshared oracle
    // byte-for-byte at every step, references must not leak (after the last
    // sharer forks or drops, no page stays marked shared), and the pool's
    // outstanding page count must return exactly to baseline once the whole
    // web drops.
    let _g = pool_lock(); // exact outstanding() deltas need serialization
    check(
        "shared-prefix CoW == unshared oracle, no page leaks",
        // See above: Miri keeps the schedule shapes, just fewer of them.
        Config::cases(if cfg!(miri) { 2 } else { 16 }),
        |rng| {
            let baseline = page_pool_stats().outstanding();
            {
                let kind = PipelineKind::all()[rng.below(6) as usize];
                let d = 4 + rng.below(9) as usize; // 4..=12
                let page_rows = 1 + rng.below(4) as usize; // 1..=4
                let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));

                // Donor prefix: 1–3 chunks, arbitrary (possibly unaligned)
                // total length, with occasional gain ramps.
                let chunks: Vec<MatF32> = (0..1 + rng.below(3) as usize)
                    .map(|_| {
                        let rows = 1 + rng.below(5) as usize;
                        let gain = [0.5, 1.0, 3.0][rng.below(3) as usize];
                        rand_mat(rng, rows, d, gain)
                    })
                    .collect();
                let mut donor = KvState::with_page_rows(kind, d, page_rows);
                for c in &chunks {
                    let _ = pipe.prefill(&mut donor, c, c, c);
                }
                let prefix_rows = donor.len();
                let snapshot = donor.share_prefix(prefix_rows);

                // Adopters + per-adopter unshared oracles (which replay the
                // donor's exact chunk schedule first).
                let n_adopt = 1 + rng.below(3) as usize;
                let mut pairs: Vec<(KvState, KvState)> = (0..n_adopt)
                    .map(|_| {
                        let mut oracle = KvState::with_page_rows(kind, d, page_rows);
                        for c in &chunks {
                            let _ = pipe.prefill(&mut oracle, c, c, c);
                        }
                        (snapshot.share_prefix(prefix_rows), oracle)
                    })
                    .collect();

                // Random interleaving of divergent appends, re-scale ramps,
                // donor divergence and retirements.
                for _ in 0..4 + rng.below(5) {
                    match rng.below(4) {
                        0 if !pairs.is_empty() => {
                            // Retire a random sharer (its refs must release).
                            let i = rng.below(pairs.len() as u64) as usize;
                            pairs.swap_remove(i);
                        }
                        1 => {
                            // Donor diverges; sharers must never notice.
                            let rows = 1 + rng.below(3) as usize;
                            let big = rand_mat(rng, rows, d, 8.0);
                            let _ = pipe.prefill(&mut donor, &big, &big, &big);
                        }
                        _ => {
                            // Every live adopter takes the same step as its
                            // oracle; magnitude jumps force re-scale forks.
                            let gain = [1.0, 6.0][rng.below(2) as usize];
                            let q = rand_mat(rng, 1, d, 1.0);
                            let kv = rand_mat(rng, 1, d, gain);
                            for (adopter, oracle) in pairs.iter_mut() {
                                let a = pipe.decode_step(adopter, &q, &kv, &kv);
                                let b = pipe.decode_step(oracle, &q, &kv, &kv);
                                assert_eq!(
                                    a.as_slice(),
                                    b.as_slice(),
                                    "{} adopter diverged from unshared oracle",
                                    kind.name()
                                );
                            }
                        }
                    }
                }

                // Drop the donor and snapshot; survivors must still decode
                // like their oracles (they own or share only live pages).
                drop(donor);
                drop(snapshot);
                let q = rand_mat(rng, 1, d, 1.0);
                let kv = rand_mat(rng, 1, d, 1.0);
                for (adopter, oracle) in pairs.iter_mut() {
                    let a = pipe.decode_step(adopter, &q, &kv, &kv);
                    let b = pipe.decode_step(oracle, &q, &kv, &kv);
                    assert_eq!(a.as_slice(), b.as_slice(), "{} after retirements", kind.name());
                }
                // With at most one sharer left per page web, nothing may
                // still be marked shared once the others are gone.
                if pairs.len() == 1 {
                    assert_eq!(
                        pairs[0].0.shared_pages(),
                        0,
                        "sole surviving sharer must own every page"
                    );
                }
            }
            // The entire web dropped: exactly as many pages released as
            // handed out — refcounts never leak a page.
            assert_eq!(
                page_pool_stats().outstanding(),
                baseline,
                "pool outstanding pages must return to baseline"
            );
        },
    );
}
