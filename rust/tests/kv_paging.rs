//! Paged-KV residency properties, driven by the in-crate miniature proptest
//! harness (`util/proptest.rs`; failing seeds are reported for exact
//! reproduction).
//!
//! The core contract under test: **paging is pure layout**. A KV state
//! paged at 1, 2 or 64 rows/page must behave byte-identically to a
//! one-big-page state (the pre-paging contiguous layout) under arbitrary
//! interleavings of multi-row appends (prefill chunks), magnitude ramps
//! (INT8 re-scale remaps across page boundaries) and single-row decode
//! steps — for every pipeline kind, including the float ones.

use intattention::attention::{
    build_pipeline, page_pool_stats, AttentionConfig, KvState, PipelineKind,
};
use intattention::tensor::MatF32;
use intattention::util::proptest::{check, Config};
use intattention::util::prng::Pcg64;

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize, gain: f32) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal() * gain).collect())
}

// Last entry exceeds any row count a schedule below reaches (≤ ~40 rows),
// so that state keeps one page per side — the pre-paging contiguous layout.
const PAGE_SIZES: [usize; 4] = [1, 2, 64, 256];

#[test]
fn prop_paged_states_bit_identical_across_interleavings() {
    check(
        "paged == contiguous under random append/rescale/decode schedules",
        Config::cases(24),
        |rng| {
            let kind = PipelineKind::all()[rng.below(6) as usize];
            let d = 4 + rng.below(13) as usize; // 4..=16
            let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
            let mut states: Vec<KvState> = PAGE_SIZES
                .iter()
                .map(|&p| KvState::with_page_rows(kind, d, p))
                .collect();
            // Random schedule of prefill blocks; occasional magnitude jumps
            // force the INT8 running-scale remap mid-history.
            let blocks = 2 + rng.below(5) as usize;
            for _ in 0..blocks {
                let rows = 1 + rng.below(5) as usize;
                let gain = match rng.below(3) {
                    0 => 0.5,
                    1 => 1.0,
                    _ => 2.0 + rng.below(5) as f32, // grows amax → rescale
                };
                let q = rand_mat(rng, rows, d, 1.0);
                let k = rand_mat(rng, rows, d, gain);
                let v = rand_mat(rng, rows, d, gain);
                let mut outs: Vec<Vec<f32>> = Vec::with_capacity(states.len());
                for st in states.iter_mut() {
                    outs.push(pipe.prefill(st, &q, &k, &v).as_slice().to_vec());
                }
                for (o, &p) in outs.iter().zip(&PAGE_SIZES) {
                    assert_eq!(
                        o, &outs[3],
                        "{} prefill at page size {p} diverged from contiguous",
                        kind.name()
                    );
                }
            }
            // Decode steps on top of the shared history.
            for _ in 0..3 {
                let q = rand_mat(rng, 1, d, 1.0);
                let k = rand_mat(rng, 1, d, 1.0);
                let v = rand_mat(rng, 1, d, 1.0);
                let mut outs: Vec<Vec<f32>> = Vec::with_capacity(states.len());
                for st in states.iter_mut() {
                    outs.push(pipe.decode_step(st, &q, &k, &v).as_slice().to_vec());
                }
                for (o, &p) in outs.iter().zip(&PAGE_SIZES) {
                    assert_eq!(
                        o, &outs[3],
                        "{} decode at page size {p} diverged from contiguous",
                        kind.name()
                    );
                }
            }
            // Structural invariants: same logical content, geometry-exact
            // accounting.
            let len = states[3].len();
            for (st, &p) in states.iter().zip(&PAGE_SIZES) {
                assert_eq!(st.len(), len);
                assert_eq!(st.rows_stored(), 2 * len);
                // ceil-rounded per side: 2 sides × ⌈len/p⌉ pages.
                assert_eq!(st.pages(), 2 * len.div_ceil(p), "page size {p}");
                assert!(st.capacity_rows() >= st.rows_stored());
            }
        },
    );
}

#[test]
fn dropped_state_pages_return_to_the_pool() {
    // Build and drop a paged state, then build another with the same
    // geometry: the pool must hand pages out of its free list (the
    // recycling that lets a retired request's memory serve the next one).
    let d = 9; // unusual head_dim → page capacities other tests don't use
    let mk = |rng: &mut Pcg64| {
        let mut st = KvState::with_page_rows(PipelineKind::IntAttention, d, 3);
        let rows = rand_mat(rng, 10, d, 1.0);
        st.append(&rows, &rows);
        assert_eq!(st.pages(), 2 * 4); // ⌈10/3⌉ per side
        st
    };
    let mut rng = Pcg64::seed_from_u64(7);
    let (_, recycled_before) = page_pool_stats();
    let st = mk(&mut rng);
    drop(st);
    let st2 = mk(&mut rng);
    let (_, recycled_after) = page_pool_stats();
    assert!(
        recycled_after > recycled_before,
        "rebuilding the same geometry after a drop must recycle pages \
         ({recycled_before} → {recycled_after})"
    );
    drop(st2);
}

#[test]
fn cloned_state_is_independent_and_equal() {
    // KvCache snapshots (tests, speculative schedulers) rely on deep
    // page-level clones: equal content, disjoint pages.
    let mut rng = Pcg64::seed_from_u64(11);
    for kind in PipelineKind::all() {
        let d = 8;
        let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
        let mut st = KvState::with_page_rows(kind, d, 2);
        let block = rand_mat(&mut rng, 5, d, 1.0);
        let _ = pipe.prefill(&mut st, &block, &block, &block);
        let mut cl = st.clone();
        assert_eq!(cl.len(), st.len());
        assert_eq!(cl.bytes(), st.bytes());
        assert_eq!(cl.pages(), st.pages());
        // Decoding on the clone must match decoding on the original...
        let q = rand_mat(&mut rng, 1, d, 1.0);
        let k = rand_mat(&mut rng, 1, d, 1.0);
        let v = rand_mat(&mut rng, 1, d, 1.0);
        let a = pipe.decode_step(&mut st, &q, &k, &v);
        let b = pipe.decode_step(&mut cl, &q, &k, &v);
        assert_eq!(a.as_slice(), b.as_slice(), "{}", kind.name());
        // ...and never aliases its pages.
        assert_eq!(st.len(), cl.len());
    }
}
