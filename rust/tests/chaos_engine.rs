//! Chaos suite: the serving engine under deterministic fault injection
//! ([`intattention::util::fault`]). Every scenario asserts the two
//! lifecycle invariants the engine guarantees:
//!
//!   1. every accepted submit receives **exactly one** terminal response,
//!      whatever faults fire (injected allocation failures, step panics,
//!      delays, cancels, deadlines, drains, hard stops);
//!   2. after the engine drains, the process-wide page pools return to
//!      their pre-test `outstanding()` baseline — no fault path leaks a
//!      page or double-frees one.
//!
//! Tests serialize on a process-local mutex: the fault plan and the pool
//! counters are process-global, so concurrent engines would race both. A
//! custom panic hook silences the *expected* injected panics (they carry a
//! typed [`fault::Injected`] payload) while real bugs keep printing.

use intattention::attention::page_pool_stats;
use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::{Engine, EngineHandle, EngineOptions, FinishReason, SubmitOptions};
use intattention::model::config::ModelConfig;
use intattention::model::weights::Weights;
use intattention::util::fault;
use intattention::util::proptest::{check, Config};
use std::sync::{Mutex, MutexGuard, Once};
use std::time::Duration;

fn weights() -> Weights {
    let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
    Weights::random(cfg, 23)
}

/// Silence panics that carry the typed injected-fault payload — they are
/// the point of this suite — without hiding genuine panics.
fn install_quiet_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<fault::Injected>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Serialized chaos context: exclusive fault-plan ownership + the pool
/// baseline the test must return to.
struct Chaos {
    _lock: MutexGuard<'static, ()>,
    baseline: u64,
}

fn chaos() -> Chaos {
    static LOCK: Mutex<()> = Mutex::new(());
    install_quiet_hook();
    // Force the engine's one-shot env arming now, so the per-scenario
    // `fault::arm_str` below is what every engine in this test observes
    // (`Engine::start` would otherwise arm the environment plan over it).
    fault::ensure_env_armed();
    // A failed test panics while holding the lock; the plan is global state
    // worth sweeping either way, so take the poisoned guard and reset.
    let lock = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm();
    Chaos { _lock: lock, baseline: page_pool_stats().outstanding() }
}

impl Chaos {
    /// Invariant 2: all pages any engine in this test held went back.
    fn assert_drained(&self, context: &str) {
        assert_eq!(
            page_pool_stats().outstanding(),
            self.baseline,
            "{context}: page pool did not return to baseline"
        );
    }
}

impl Drop for Chaos {
    fn drop(&mut self) {
        fault::disarm();
    }
}

fn engine() -> EngineHandle {
    Engine::start(weights(), EngineOptions::default())
}

const LONG: Duration = Duration::from_secs(120);

#[test]
fn injected_prefill_panic_poisons_only_its_request() {
    let c = chaos();
    let panics_before = fault::stats().injected_panics;
    // Two requests admitted in the same round; shortest-first admission
    // makes the 3-token prompt the first prefill step — and the fault's
    // victim. The 10-token request must be untouched.
    fault::arm_str("panic_prefill@1").unwrap();
    let h = engine();
    let victim = h.submit(vec![1, 2, 3], 3, SubmitOptions::default()).unwrap();
    let bystander_prompt: Vec<u16> = (0..10).map(|i| (i * 3 % 32) as u16).collect();
    let bystander = h.submit(bystander_prompt.clone(), 4, SubmitOptions::default()).unwrap();
    let v = victim.recv_all_timeout(LONG).unwrap();
    let b = bystander.recv_all_timeout(LONG).unwrap();
    assert_eq!(v.finish, FinishReason::Error, "victim retires poisoned");
    assert!(v.tokens.is_empty(), "panicked before its first token");
    assert_eq!(b.finish, FinishReason::Done);
    assert_eq!(b.tokens.len(), 4);
    let snap = h.shutdown();
    assert_eq!(snap.finished_error, 1);
    assert_eq!(snap.finished_done, 1);
    assert!(snap.fault_injected_panics >= panics_before + 1, "panic counter advanced");
    // The bystander's output is byte-identical to a fault-free run: the
    // caught panic touched nothing outside the victim's own cache.
    fault::disarm();
    let clean = engine();
    let rx = clean.submit(bystander_prompt, 4, SubmitOptions::default()).unwrap();
    assert_eq!(rx.recv_all_timeout(LONG).unwrap().tokens, b.tokens);
    clean.shutdown();
    c.assert_drained("prefill panic");
}

#[test]
fn injected_decode_panic_spares_the_rest_of_the_batch() {
    let c = chaos();
    // The 3-token request always reaches decode first (submitted first AND
    // shortest-first admission), so the first decode-step fault names it —
    // whether or not the 9-token request shares its batch that round. Only
    // the victim may fail.
    fault::arm_str("panic_decode@1").unwrap();
    let h = engine();
    let victim = h.submit(vec![1, 2, 3], 6, SubmitOptions::default()).unwrap();
    let bystander_prompt: Vec<u16> = (0..9).map(|i| (i * 5 % 32) as u16).collect();
    let bystander = h.submit(bystander_prompt.clone(), 6, SubmitOptions::default()).unwrap();
    let v = victim.recv_all_timeout(LONG).unwrap();
    let b = bystander.recv_all_timeout(LONG).unwrap();
    assert_eq!(v.finish, FinishReason::Error);
    assert!(
        !v.tokens.is_empty() && v.tokens.len() < 6,
        "victim finished prefill (first token sampled) but died in decode ({} tokens)",
        v.tokens.len()
    );
    assert_eq!(b.finish, FinishReason::Done);
    assert_eq!(b.tokens.len(), 6);
    // The engine keeps serving after the caught panic.
    let rx = h.submit(vec![4, 5], 2, SubmitOptions::default()).unwrap();
    assert_eq!(rx.recv_all_timeout(LONG).unwrap().finish, FinishReason::Done);
    let snap = h.shutdown();
    assert_eq!(snap.finished_error, 1);
    assert_eq!(snap.finished_done, 2);
    // Bit-equality with a fault-free run: the victim's panic fired at step
    // entry, before any batch-mate's cache was touched.
    fault::disarm();
    let clean = engine();
    let rx = clean.submit(bystander_prompt, 6, SubmitOptions::default()).unwrap();
    assert_eq!(rx.recv_all_timeout(LONG).unwrap().tokens, b.tokens);
    clean.shutdown();
    c.assert_drained("decode panic");
}

#[test]
fn injected_page_allocation_failure_is_survivable() {
    let c = chaos();
    let allocs_before = fault::stats().failed_allocs;
    // The very first page acquisition (the victim's first prefill KV page)
    // fails. The request poisons; the engine, the pool accounting and the
    // next request survive.
    fault::arm_str("pool_alloc@1").unwrap();
    let h = engine();
    let rx = h.submit(vec![1, 2, 3, 4], 3, SubmitOptions::default()).unwrap();
    let resp = rx.recv_all_timeout(LONG).unwrap();
    assert_eq!(resp.finish, FinishReason::Error);
    assert!(resp.tokens.is_empty());
    // Ordinal faults are one-shot: the retry allocates normally.
    let rx = h.submit(vec![1, 2, 3, 4], 3, SubmitOptions::default()).unwrap();
    let resp = rx.recv_all_timeout(LONG).unwrap();
    assert_eq!(resp.finish, FinishReason::Done);
    assert_eq!(resp.tokens.len(), 3);
    let snap = h.shutdown();
    assert_eq!(snap.finished_error, 1);
    assert_eq!(snap.finished_done, 1);
    assert_eq!(fault::stats().failed_allocs, allocs_before + 1);
    c.assert_drained("pool alloc failure");
}

#[cfg(not(miri))] // wall-clock scenario: injected delays pace real rounds
#[test]
fn graceful_drain_finishes_inflight_and_answers_queued() {
    let c = chaos();
    // Slow decode rounds give the drain something to finish; max_active 1
    // keeps the two trailing requests queued until the drain answers them.
    fault::arm_str("delay_decode=5ms").unwrap();
    let opts = EngineOptions {
        policy: BatchPolicy { max_active: 1, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(weights(), opts);
    let inflight = h.submit(vec![1, 2, 3], 30, SubmitOptions::default()).unwrap();
    // Only proceed once that request is provably in flight: submitted later,
    // the shorter prompts below would win shortest-first admission, and a
    // drain before admission legitimately answers it Cancelled instead.
    let started = std::time::Instant::now();
    while h.metrics().prefill_tokens < 3 {
        assert!(started.elapsed() < LONG, "first request never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }
    let queued: Vec<_> =
        (0..2).map(|i| h.submit(vec![4, (5 + i) as u16], 2, SubmitOptions::default()).unwrap()).collect();
    let snap = h.shutdown();
    let r = inflight.recv_all_timeout(LONG).unwrap();
    assert_eq!(r.finish, FinishReason::Done, "in-flight decode runs to completion");
    assert_eq!(r.tokens.len(), 30);
    for rx in queued {
        let r = rx.recv_all_timeout(LONG).unwrap();
        assert_eq!(r.finish, FinishReason::Cancelled, "queued work answered, not dropped");
        assert!(r.tokens.is_empty());
    }
    assert_eq!(snap.finished_done, 1);
    assert_eq!(snap.finished_cancelled, 2);
    assert!(snap.drain_us > 0, "drain duration recorded");
    c.assert_drained("graceful drain");
}

#[cfg(not(miri))] // wall-clock scenario: hard-stop timeout vs delayed rounds
#[test]
fn drain_hard_stop_cancels_a_stuck_request() {
    let c = chaos();
    // 5 ms per decode step × a context-bound request ≈ 300 ms of drain —
    // far past the 30 ms hard stop, which must cancel it with partials.
    fault::arm_str("delay_decode=5ms").unwrap();
    let opts = EngineOptions { drain_timeout: Duration::from_millis(30), ..Default::default() };
    let h = Engine::start(weights(), opts);
    let rx = h.submit(vec![1, 2, 3], 1000, SubmitOptions::default()).unwrap();
    let started = std::time::Instant::now();
    while h.metrics().prefill_tokens < 3 {
        assert!(started.elapsed() < LONG, "request never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }
    let snap = h.shutdown();
    let r = rx.recv_all_timeout(LONG).unwrap();
    assert_eq!(r.finish, FinishReason::Cancelled, "hard stop answers the stuck request");
    assert!(!r.tokens.is_empty(), "partial output survives the hard stop");
    assert_eq!(snap.finished_cancelled, 1);
    assert!(snap.drain_us >= 30_000, "drain ran to the hard stop ({} us)", snap.drain_us);
    c.assert_drained("hard stop");
}

#[cfg(not(miri))] // wall-clock scenario: deadline vs delayed decode rounds
#[test]
fn deadline_trips_mid_decode_with_partial_output() {
    let c = chaos();
    fault::arm_str("delay_decode=5ms").unwrap();
    let h = engine();
    let opts = SubmitOptions::default().with_deadline(Duration::from_millis(60));
    let rx = h.submit(vec![1, 2, 3], 50, opts).unwrap();
    let r = rx.recv_all_timeout(LONG).unwrap();
    assert_eq!(r.finish, FinishReason::DeadlineExceeded);
    assert!(r.tokens.len() < 50, "deadline must cut the run short");
    // The engine keeps serving; an undeadlined request completes.
    let rx = h.submit(vec![4, 5, 6], 2, SubmitOptions::default()).unwrap();
    assert_eq!(rx.recv_all_timeout(LONG).unwrap().finish, FinishReason::Done);
    let snap = h.shutdown();
    assert_eq!(snap.finished_deadline, 1);
    assert_eq!(snap.finished_done, 1);
    c.assert_drained("deadline");
}

#[test]
fn randomized_fault_schedules_never_lose_or_duplicate_a_response() {
    let c = chaos();
    let baseline = c.baseline;
    // Reduced case count under Miri (each case serves a full engine).
    let cases = if cfg!(miri) { 2 } else { 10 };
    // `seed=N` in the environment plan retargets the schedule, and the
    // driver's failure message names the exact reproducing seed.
    let base_seed = fault::env_seed().unwrap_or(0xC4A05);
    check(
        "chaos: exactly one terminal response per submit, pool drains",
        Config { cases, base_seed },
        |rng| {
            let mut clauses: Vec<String> = Vec::new();
            if rng.below(2) == 0 {
                clauses.push(format!("pool_alloc@{}", 1 + rng.below(16)));
            }
            if rng.below(2) == 0 {
                clauses.push(format!("panic_prefill@{}", 1 + rng.below(8)));
            }
            if rng.below(2) == 0 {
                clauses.push(format!("panic_decode@{}", 1 + rng.below(24)));
            }
            if !cfg!(miri) && rng.below(3) == 0 {
                let site = ["delay_prefill", "delay_decode", "delay_round"]
                    [rng.below(3) as usize];
                clauses.push(format!("{site}={}us", 100 * (1 + rng.below(10))));
            }
            fault::arm_str(&clauses.join(",")).unwrap();

            let h = engine();
            let n = if cfg!(miri) { 2 } else { 3 + rng.below(5) as usize };
            let mut rxs = Vec::with_capacity(n);
            for i in 0..n {
                let plen = 2 + rng.below(12) as usize;
                let prompt: Vec<u16> =
                    (0..plen).map(|j| ((i * 7 + j * 3) % 32) as u16).collect();
                let gen = 1 + rng.below(5) as usize;
                let mut opts = SubmitOptions::default();
                if rng.below(5) == 0 {
                    opts = opts.with_deadline(Duration::from_millis(rng.below(3)));
                }
                let rx = h.submit(prompt, gen, opts).unwrap();
                if rng.below(4) == 0 {
                    rx.cancel();
                }
                rxs.push(rx);
            }
            let snap = h.shutdown();
            // Invariant 1: exactly one terminal response each — present
            // after the drain, and never followed by a second.
            for (i, mut rx) in rxs.into_iter().enumerate() {
                let resp = rx
                    .recv_final_timeout(LONG)
                    .unwrap_or_else(|e| panic!("request {i} got no terminal response: {e:?}"));
                assert!(
                    resp.tokens.len() <= 64,
                    "request {i}: impossible output length {}",
                    resp.tokens.len()
                );
                assert!(rx.try_recv().is_err(), "request {i} got a second response");
            }
            let by_reason = snap.finished_done
                + snap.finished_length
                + snap.finished_cancelled
                + snap.finished_deadline
                + snap.finished_error;
            assert_eq!(snap.completed, n as u64, "every submit reached a terminal state");
            assert_eq!(by_reason, snap.completed, "finish reasons partition completions");
            // Invariant 2: whatever died, every page came back.
            assert_eq!(
                page_pool_stats().outstanding(),
                baseline,
                "page pool did not drain (plan `{}`)",
                clauses.join(",")
            );
        },
    );
}
