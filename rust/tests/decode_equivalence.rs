//! Stateful-path equivalence: chunked prefill + incremental decode through
//! the per-sequence KV states must reproduce the one-shot causal `forward`
//! for **every** `PipelineKind` (and the grouped-Q schemes of §3.3).
//!
//! The integer pipelines are not bit-identical across the two paths — the
//! query block is quantized per call and the resident K/V scale is a running
//! maximum — but the divergence is bounded by one quantization LSB here and
//! there, so the outputs must agree to cosine ≥ 0.999.

use intattention::attention::int_attention::IntAttention;
use intattention::attention::{
    build_pipeline, AttentionConfig, AttentionPipeline, KvState, PipelineKind,
};
use intattention::quant::GroupScheme;
use intattention::tensor::MatF32;
use intattention::util::prng::Pcg64;
use intattention::util::stats::cosine_similarity;
use intattention::util::threadpool::ParallelPool;

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

fn rows_of(m: &MatF32, r0: usize, r1: usize) -> MatF32 {
    let c = m.cols();
    MatF32::from_vec(r1 - r0, c, m.as_slice()[r0 * c..r1 * c].to_vec())
}

/// Run chunked prefill (two uneven chunks) + single-token decode steps over
/// a stateful pipeline; return the row-concatenated outputs.
fn incremental_output(
    pipe: &mut dyn AttentionPipeline,
    st: &mut KvState,
    q: &MatF32,
    k: &MatF32,
    v: &MatF32,
    prefill_rows: usize,
) -> MatF32 {
    let l = q.rows();
    let split = prefill_rows * 5 / 8; // uneven chunks exercise the offsets
    let mut out = Vec::with_capacity(q.len());
    for (r0, r1) in [(0, split), (split, prefill_rows)] {
        let o = pipe.prefill(st, &rows_of(q, r0, r1), &rows_of(k, r0, r1), &rows_of(v, r0, r1));
        out.extend_from_slice(o.as_slice());
    }
    for r in prefill_rows..l {
        let o = pipe.decode_step(
            st,
            &rows_of(q, r, r + 1),
            &rows_of(k, r, r + 1),
            &rows_of(v, r, r + 1),
        );
        out.extend_from_slice(o.as_slice());
    }
    MatF32::from_vec(l, q.cols(), out)
}

#[test]
fn incremental_matches_one_shot_for_every_pipeline_kind() {
    let (l, d, prefill) = (64, 32, 48);
    for (seed, kind) in PipelineKind::all().into_iter().enumerate() {
        let mut rng = Pcg64::seed_from_u64(100 + seed as u64);
        let q = rand_mat(&mut rng, l, d);
        let k = rand_mat(&mut rng, l, d);
        let v = rand_mat(&mut rng, l, d);
        let want = build_pipeline(kind, AttentionConfig::new(l, d).causal()).forward(&q, &k, &v);
        let mut pipe = build_pipeline(kind, AttentionConfig::new(l, d));
        let mut st = pipe.begin_state();
        let got = incremental_output(pipe.as_mut(), &mut st, &q, &k, &v, prefill);
        assert_eq!(st.len(), l, "{}", kind.name());
        let cos = cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos >= 0.999, "{}: incremental vs one-shot cos={cos}", kind.name());
        assert!(got.as_slice().iter().all(|x| x.is_finite()), "{}", kind.name());
    }
}

#[test]
fn incremental_matches_one_shot_for_grouped_q_schemes() {
    let (l, d, prefill) = (64, 32, 40);
    for (i, scheme) in [GroupScheme::PerRow, GroupScheme::PerRowBlock(8)]
        .into_iter()
        .enumerate()
    {
        let mut rng = Pcg64::seed_from_u64(200 + i as u64);
        let q = rand_mat(&mut rng, l, d);
        let k = rand_mat(&mut rng, l, d);
        let v = rand_mat(&mut rng, l, d);
        let want = IntAttention::new(AttentionConfig::new(l, d).causal())
            .with_q_scheme(scheme)
            .forward(&q, &k, &v);
        let mut pipe = IntAttention::new(AttentionConfig::new(l, d)).with_q_scheme(scheme);
        let mut st = pipe.begin_state();
        let got = incremental_output(&mut pipe, &mut st, &q, &k, &v, prefill);
        let cos = cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos >= 0.999, "{scheme:?}: incremental vs one-shot cos={cos}");
    }
}

#[test]
fn rescale_path_keeps_fidelity_under_growing_magnitudes() {
    // K/V rows whose magnitude ramps up over the sequence force the running
    // abs-max to grow repeatedly — the INT8 states must re-map history and
    // stay faithful to the one-shot result (which quantizes with the final,
    // widest scale from the start).
    let (l, d, prefill) = (48, 16, 24);
    let mut rng = Pcg64::seed_from_u64(300);
    let q = rand_mat(&mut rng, l, d);
    let mut k = rand_mat(&mut rng, l, d);
    let mut v = rand_mat(&mut rng, l, d);
    for r in 0..l {
        let gain = 1.0 + r as f32 * 0.25; // 1× → 12.75× across the sequence
        for x in k.row_mut(r) {
            *x *= gain;
        }
        for x in v.row_mut(r) {
            *x *= gain;
        }
    }
    for kind in [PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let want = build_pipeline(kind, AttentionConfig::new(l, d).causal()).forward(&q, &k, &v);
        let mut pipe = build_pipeline(kind, AttentionConfig::new(l, d));
        let mut st = pipe.begin_state();
        let got = incremental_output(pipe.as_mut(), &mut st, &q, &k, &v, prefill);
        let inner = st.as_int8();
        assert!(
            inner.k.rescales > 0,
            "{}: ramping magnitudes must trigger the re-scale path",
            kind.name()
        );
        // The running scale converged to the one-shot (global) scale, so the
        // re-mapped history costs at most a little extra rounding noise.
        let cos = cosine_similarity(got.as_slice(), want.as_slice());
        assert!(cos >= 0.995, "{}: rescale fidelity cos={cos}", kind.name());
    }
}

#[test]
fn paged_states_byte_identical_across_page_sizes() {
    // The paged-KV acceptance criterion: paging is pure layout. For every
    // pipeline kind, an identical chunked-prefill + decode schedule over
    // states paged at 1, 2 and 64 rows/page produces outputs **byte-equal**
    // to a one-big-page state (page size 128 ≥ every row appended here —
    // exactly the pre-paging contiguous layout): rows hold the same values
    // and every kernel computes the same per-row products in the same
    // order, pages or not. l = 80 > 64 so even 64-row pages split, and
    // ramping K/V magnitudes force the INT8 re-scale remap to run its page
    // walk, too.
    let (l, d, prefill) = (80, 16, 40);
    for kind in PipelineKind::all() {
        let mut rng = Pcg64::seed_from_u64(1000);
        let q = rand_mat(&mut rng, l, d);
        let mut k = rand_mat(&mut rng, l, d);
        let mut v = rand_mat(&mut rng, l, d);
        for r in 0..l {
            let gain = 1.0 + r as f32 * 0.1;
            for x in k.row_mut(r) {
                *x *= gain;
            }
            for x in v.row_mut(r) {
                *x *= gain;
            }
        }
        let mut pipe = build_pipeline(kind, AttentionConfig::new(l, d));
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for page_rows in [1usize, 2, 64, 128] {
            let mut st = KvState::with_page_rows(kind, d, page_rows);
            let got = incremental_output(pipe.as_mut(), &mut st, &q, &k, &v, prefill);
            assert_eq!(st.len(), l, "{}", kind.name());
            if page_rows == 128 {
                assert_eq!(st.pages(), 2, "one page per side = contiguous layout");
            }
            outs.push(got.as_slice().to_vec());
        }
        let oracle = outs.last().unwrap().clone();
        for (got, &pr) in outs.iter().zip(&[1usize, 2, 64]) {
            assert_eq!(
                got, &oracle,
                "{} at page size {pr}: paged output must be byte-identical to contiguous",
                kind.name()
            );
        }
    }
}

/// Quantized/native resident bytes + scale bookkeeping of a state, for
/// exact content comparison across the shared and unshared paths.
fn state_fingerprint(st: &KvState) -> Vec<u64> {
    let mut out = Vec::new();
    match st {
        KvState::F32(s) => {
            out.extend(s.k.iter().map(|x| x.to_bits() as u64));
            out.extend(s.v.iter().map(|x| x.to_bits() as u64));
        }
        KvState::F16(s) => {
            out.extend(s.k.iter().map(|x| x.0 as u64));
            out.extend(s.v.iter().map(|x| x.0 as u64));
        }
        KvState::Int8(s) => {
            out.extend(s.k.data.iter().map(|&x| x as u8 as u64));
            out.extend(s.v.data.iter().map(|&x| x as u8 as u64));
            out.push(s.k.scale.to_bits() as u64);
            out.push(s.v.scale.to_bits() as u64);
            out.push(s.k.amax.to_bits() as u64);
            out.push(s.v.amax.to_bits() as u64);
        }
    }
    out
}

#[test]
fn shared_prefix_outputs_byte_identical_to_unshared() {
    // The prefix-sharing acceptance criterion: a state that ADOPTS a shared
    // prefix (copy-on-write page references + pinned scales) and then runs
    // a suffix schedule must produce outputs — and resident bytes — exactly
    // equal to a state that computed the whole schedule itself, for every
    // pipeline kind. The donor then diverges with large-magnitude appends
    // (forcing its INT8 re-scale to remap); the adopter must be unaffected
    // because the remap forks the shared pages instead of rewriting them.
    let (d, page_rows) = (16, 4);
    // Prefix: two chunks ending page-aligned at row 12; suffix: one 5-row
    // chunk + decode steps. The oracle runs the SAME boundaries (sharing is
    // only byte-invisible under an identical chunk schedule — the integer
    // pipelines quantize each chunk's query block per call).
    let (prefix_rows, l) = (12, 20);
    let chunk_bounds = [(0usize, 6usize), (6, 12), (12, 17)];
    for kind in PipelineKind::all() {
        let mut rng = Pcg64::seed_from_u64(1400);
        let q = rand_mat(&mut rng, l, d);
        let mut k = rand_mat(&mut rng, l, d);
        let mut v = rand_mat(&mut rng, l, d);
        for r in 0..l {
            let gain = 1.0 + r as f32 * 0.2; // force re-scales along the way
            for x in k.row_mut(r).iter_mut().chain(v.row_mut(r)) {
                *x *= gain;
            }
        }
        let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));

        // Donor computes the prefix; snapshot shares it at exactly len().
        let chunk_of =
            |st: &mut KvState, pipe: &mut dyn AttentionPipeline, r0: usize, r1: usize| {
                pipe.prefill(st, &rows_of(&q, r0, r1), &rows_of(&k, r0, r1), &rows_of(&v, r0, r1))
            };
        let mut donor = KvState::with_page_rows(kind, d, page_rows);
        for &(r0, r1) in &chunk_bounds[..2] {
            let _ = chunk_of(&mut donor, pipe.as_mut(), r0, r1);
        }
        let snapshot = donor.share_prefix(prefix_rows);

        // Unshared oracle: full schedule from scratch.
        let mut oracle = KvState::with_page_rows(kind, d, page_rows);
        let mut oracle_out: Vec<f32> = Vec::new();
        for &(r0, r1) in &chunk_bounds {
            let o = chunk_of(&mut oracle, pipe.as_mut(), r0, r1);
            oracle_out.extend_from_slice(o.as_slice());
        }

        // Adopter: shared prefix + the same suffix schedule.
        let mut adopter = snapshot.share_prefix(prefix_rows);
        assert!(adopter.shared_pages() > 0, "{}: adoption must alias pages", kind.name());
        let (r0, r1) = chunk_bounds[2];
        let adopter_out = chunk_of(&mut adopter, pipe.as_mut(), r0, r1);
        // Suffix prefill outputs must match the oracle's suffix rows.
        assert_eq!(
            adopter_out.as_slice(),
            &oracle_out[prefix_rows * d..],
            "{}: shared suffix prefill must be byte-identical",
            kind.name()
        );

        // Donor diverges hard: huge rows grow its running abs-max, so its
        // re-scale remap runs — over pages the snapshot/adopter still hold.
        let mut big = rand_mat(&mut rng, 2, d);
        for x in big.as_mut_slice() {
            *x *= 40.0;
        }
        let _ = pipe.prefill(&mut donor, &rand_mat(&mut rng, 2, d), &big, &big);

        // Decode steps on the adopter vs the oracle: still byte-identical,
        // including the resident state content.
        for r in 17..l {
            let (q1, k1, v1) =
                (rows_of(&q, r, r + 1), rows_of(&k, r, r + 1), rows_of(&v, r, r + 1));
            let a = pipe.decode_step(&mut adopter, &q1, &k1, &v1);
            let b = pipe.decode_step(&mut oracle, &q1, &k1, &v1);
            assert_eq!(
                a.as_slice(),
                b.as_slice(),
                "{}: decode at row {r} diverged after donor re-scale",
                kind.name()
            );
        }
        assert_eq!(
            state_fingerprint(&adopter),
            state_fingerprint(&oracle),
            "{}: resident bytes/scales must match the unshared oracle",
            kind.name()
        );
    }
}

#[test]
fn unaligned_share_forks_tail_page_on_first_divergent_append() {
    // A share whose boundary lands mid-page aliases the tail page too; the
    // first divergent append on the adopter must fork it (copy-on-write)
    // and still reproduce the unshared oracle byte-for-byte — while the
    // donor's resident bytes survive untouched.
    let (d, page_rows, prefix_rows) = (8, 4, 6); // 6 rows = 1.5 pages
    for kind in PipelineKind::all() {
        let mut rng = Pcg64::seed_from_u64(1500);
        let block = rand_mat(&mut rng, prefix_rows, d);
        let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d));
        let mut donor = KvState::with_page_rows(kind, d, page_rows);
        let _ = pipe.prefill(&mut donor, &block, &block, &block);
        let donor_before = state_fingerprint(&donor);

        let mut oracle = KvState::with_page_rows(kind, d, page_rows);
        let _ = pipe.prefill(&mut oracle, &block, &block, &block);

        let mut adopter = donor.share_prefix(prefix_rows);
        assert_eq!(adopter.shared_pages(), 4, "{}: 2 pages × K/V shared", kind.name());
        for r in 0..3 {
            let (q1, k1, v1) = (
                rand_mat(&mut rng, 1, d),
                rand_mat(&mut rng, 1, d),
                rand_mat(&mut rng, 1, d),
            );
            let a = pipe.decode_step(&mut adopter, &q1, &k1, &v1);
            let b = pipe.decode_step(&mut oracle, &q1, &k1, &v1);
            assert_eq!(a.as_slice(), b.as_slice(), "{} decode {r}", kind.name());
        }
        assert_eq!(state_fingerprint(&adopter), state_fingerprint(&oracle), "{}", kind.name());
        assert_eq!(
            state_fingerprint(&donor),
            donor_before,
            "{}: donor must never observe the adopter's appends",
            kind.name()
        );
    }
}

#[test]
fn batched_decode_bit_identical_to_sequential_for_every_pipeline_kind() {
    // decode_step_batch must be *bit-identical* to B sequential decode_step
    // calls for every pipeline kind AND every pool width: the integer GEMMs
    // are exact, and every float operation in the batched paths is the same
    // per-sequence expression evaluated in the same order — the persistent
    // runtime's dynamic chunking only moves whole per-sequence products
    // between workers. Grain 1 forces the multi-worker pools to genuinely
    // dispatch these small launches (the default grain would run them
    // inline, proving nothing).
    let d = 16;
    let ctxs = [1usize, 3, 7, 12, 5, 20, 9, 16]; // ragged batch of 8
    let pools: Vec<&'static ParallelPool> = [1usize, 2, 8]
        .iter()
        .map(|&t| ParallelPool::with_grain(t, 1).leak())
        .collect();
    for kind in PipelineKind::all() {
        for &pool in &pools {
            let mut rng = Pcg64::seed_from_u64(700);
            let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d).with_pool(pool));
            // Build B independent states with per-sequence histories.
            let mut st_seq: Vec<KvState> = Vec::new();
            for &ctx in &ctxs {
                let mut st = pipe.begin_state();
                let (q, k, v) = (
                    rand_mat(&mut rng, ctx, d),
                    rand_mat(&mut rng, ctx, d),
                    rand_mat(&mut rng, ctx, d),
                );
                let _ = pipe.prefill(&mut st, &q, &k, &v);
                st_seq.push(st);
            }
            let mut st_bat: Vec<KvState> = st_seq.clone();
            let b = ctxs.len();
            for round in 0..4 {
                let q = rand_mat(&mut rng, b, d);
                let k = rand_mat(&mut rng, b, d);
                let v = rand_mat(&mut rng, b, d);
                // Sequential oracle.
                let mut want = Vec::with_capacity(b * d);
                for (i, st) in st_seq.iter_mut().enumerate() {
                    let o = pipe.decode_step(
                        st,
                        &rows_of(&q, i, i + 1),
                        &rows_of(&k, i, i + 1),
                        &rows_of(&v, i, i + 1),
                    );
                    want.extend_from_slice(o.as_slice());
                }
                // One grouped call.
                let mut refs: Vec<&mut KvState> = st_bat.iter_mut().collect();
                let got = pipe.decode_step_batch(&mut refs, &q, &k, &v);
                assert_eq!(
                    got.as_slice(),
                    &want[..],
                    "{} round {round} pool {}: batched decode must be bit-identical",
                    kind.name(),
                    pool.size()
                );
            }
            // The resident states advanced identically too.
            for ((a, b_), &ctx) in st_seq.iter().zip(&st_bat).zip(&ctxs) {
                assert_eq!(a.len(), ctx + 4, "{}", kind.name());
                assert_eq!(a.len(), b_.len(), "{}", kind.name());
                assert_eq!(a.bytes(), b_.bytes(), "{}", kind.name());
            }
        }
    }
}

#[test]
fn batched_decode_identical_across_pool_sizes() {
    // Stronger cross-width check: the *batched* outputs themselves must be
    // byte-equal between a 1-thread (inline) pool and forced multi-worker
    // pools — decode results can never depend on how many workers the
    // runtime happens to have.
    let d = 16;
    let ctxs = [2usize, 9, 5, 14];
    let b = ctxs.len();
    let pools: Vec<&'static ParallelPool> = [1usize, 2, 8]
        .iter()
        .map(|&t| ParallelPool::with_grain(t, 1).leak())
        .collect();
    for kind in PipelineKind::all() {
        let mut outs: Vec<Vec<f32>> = Vec::new();
        for &pool in &pools {
            let mut rng = Pcg64::seed_from_u64(900);
            let mut pipe = build_pipeline(kind, AttentionConfig::new(0, d).with_pool(pool));
            let mut states: Vec<KvState> = Vec::new();
            for &ctx in &ctxs {
                let mut st = pipe.begin_state();
                let (q, k, v) = (
                    rand_mat(&mut rng, ctx, d),
                    rand_mat(&mut rng, ctx, d),
                    rand_mat(&mut rng, ctx, d),
                );
                let _ = pipe.prefill(&mut st, &q, &k, &v);
                states.push(st);
            }
            let mut run_out: Vec<f32> = Vec::new();
            for _ in 0..3 {
                let q = rand_mat(&mut rng, b, d);
                let k = rand_mat(&mut rng, b, d);
                let v = rand_mat(&mut rng, b, d);
                let mut refs: Vec<&mut KvState> = states.iter_mut().collect();
                run_out.extend_from_slice(pipe.decode_step_batch(&mut refs, &q, &k, &v).as_slice());
            }
            outs.push(run_out);
        }
        assert_eq!(outs[0], outs[1], "{}: pool 1 vs 2", kind.name());
        assert_eq!(outs[0], outs[2], "{}: pool 1 vs 8", kind.name());
    }
}

#[test]
fn batched_decode_matches_default_sequential_impl_for_grouped_q() {
    // IntAttention's grouped-Q schemes ride the same batched path; cross-
    // check one of them against B single-sequence `decode_step` calls
    // (batch-width invariance — `decode_step` itself routes through the
    // batched implementation with B = 1).
    let d = 16;
    let ctxs = [4usize, 11, 2];
    let mut rng = Pcg64::seed_from_u64(800);
    let mut pipe = IntAttention::new(AttentionConfig::new(0, d)).with_q_scheme(GroupScheme::PerRow);
    let mut st_seq: Vec<KvState> = Vec::new();
    for &ctx in &ctxs {
        let mut st = pipe.begin_state();
        let (q, k, v) = (
            rand_mat(&mut rng, ctx, d),
            rand_mat(&mut rng, ctx, d),
            rand_mat(&mut rng, ctx, d),
        );
        let _ = pipe.prefill(&mut st, &q, &k, &v);
        st_seq.push(st);
    }
    let mut st_bat = st_seq.clone();
    let b = ctxs.len();
    let q = rand_mat(&mut rng, b, d);
    let k = rand_mat(&mut rng, b, d);
    let v = rand_mat(&mut rng, b, d);
    let mut want = Vec::new();
    for (i, st) in st_seq.iter_mut().enumerate() {
        let o = pipe.decode_step(
            st,
            &rows_of(&q, i, i + 1),
            &rows_of(&k, i, i + 1),
            &rows_of(&v, i, i + 1),
        );
        want.extend_from_slice(o.as_slice());
    }
    let mut refs: Vec<&mut KvState> = st_bat.iter_mut().collect();
    let got = pipe.decode_step_batch(&mut refs, &q, &k, &v);
    assert_eq!(got.as_slice(), &want[..], "grouped-Q batched decode must be bit-identical");
}

#[test]
fn decode_conversion_work_is_independent_of_context() {
    // The acceptance criterion behind the decode-throughput bench, asserted
    // deterministically and for BOTH decode implementations (the toggle is
    // forced both ways, so this does not depend on `INTATTN_FUSED_DECODE`):
    // per-token dtype conversions do not grow with the resident context for
    // any stateful pipeline except the Quant-Only detour.
    let d = 32;
    for fused in [false, true] {
        for kind in PipelineKind::all() {
            let mut rng = Pcg64::seed_from_u64(400);
            let mut pipe =
                build_pipeline(kind, AttentionConfig::new(8, d).with_fused_decode(fused));
            let mut st = pipe.begin_state();
            let (q, k, v) =
                (rand_mat(&mut rng, 8, d), rand_mat(&mut rng, 8, d), rand_mat(&mut rng, 8, d));
            let _ = pipe.prefill(&mut st, &q, &k, &v);
            let mut deltas = Vec::new();
            let mut prev = pipe.op_counts().dtype_conv;
            for _ in 0..16 {
                let q1 = rand_mat(&mut rng, 1, d);
                // Damped K/V rows keep the running amax flat so the INT8
                // states' (op-counted) re-scale path cannot fire — its cost
                // is covered by the dedicated rescale test, not this
                // invariant.
                let mut k1 = rand_mat(&mut rng, 1, d);
                let mut v1 = rand_mat(&mut rng, 1, d);
                for x in k1.as_mut_slice().iter_mut().chain(v1.as_mut_slice()) {
                    *x *= 0.5;
                }
                let _ = pipe.decode_step(&mut st, &q1, &k1, &v1);
                let now = pipe.op_counts().dtype_conv;
                deltas.push(now - prev);
                prev = now;
            }
            // Quant-Only's detour converts the whole (growing) logit row
            // each step, so only its deltas may grow. Unfused EXAQ
            // requantizes its P row (grows with context) but never the K/V
            // history: growth per step is exactly one element. The fused
            // EXAQ walk keeps probabilities in float end to end, so the
            // per-element requantize disappears and it joins the flat set.
            let is_exaq = kind == PipelineKind::ExaqInt2 || kind == PipelineKind::ExaqInt3;
            if kind == PipelineKind::QuantOnly {
                assert!(
                    deltas.windows(2).all(|w| w[1] >= w[0]),
                    "{}: {:?}",
                    kind.name(),
                    deltas
                );
            } else if is_exaq && !fused {
                let diffs: Vec<u64> = deltas.windows(2).map(|w| w[1] - w[0]).collect();
                assert!(diffs.iter().all(|&x| x == 1), "{}: {:?}", kind.name(), diffs);
            } else {
                assert!(
                    deltas.windows(2).all(|w| w[0] == w[1]),
                    "{} (fused={fused}): conversions must be O(1) per token, got {:?}",
                    kind.name(),
                    deltas
                );
            }
        }
    }
}

#[test]
fn fused_decode_matches_unfused_oracle_within_bounds() {
    // Fidelity contract of the fused walk (documented in
    // `attention::int_attention` / `attention::exaq_pipe`): the fused path
    // accumulates un-normalized Ê·V̂ and normalizes once per output lane,
    // where the unfused oracle rounds every P̂ element to its probability
    // grid *before* aggregating — so the two differ by the accumulated
    // per-element rounding, a sub-percent effect on any real row. EXAQ's
    // fused clip additionally lags one token (it is derived from the
    // pre-step running σ, since the walk cannot see this step's Δ
    // distribution before gathering). Asserted as per-step cosine ≥ 0.999
    // over a decode run long enough to cross several re-scale-free steps.
    let (d, prefill_rows, steps) = (32, 24, 12);
    let kinds =
        [PipelineKind::IntAttention, PipelineKind::ExaqInt2, PipelineKind::ExaqInt3];
    for (i, kind) in kinds.into_iter().enumerate() {
        let mut rng = Pcg64::seed_from_u64(2000 + i as u64);
        let mut fused = build_pipeline(kind, AttentionConfig::new(0, d).with_fused_decode(true));
        let mut plain = build_pipeline(kind, AttentionConfig::new(0, d).with_fused_decode(false));
        let mut st_f = fused.begin_state();
        let mut st_u = plain.begin_state();
        let (q, k, v) = (
            rand_mat(&mut rng, prefill_rows, d),
            rand_mat(&mut rng, prefill_rows, d),
            rand_mat(&mut rng, prefill_rows, d),
        );
        let _ = fused.prefill(&mut st_f, &q, &k, &v);
        let _ = plain.prefill(&mut st_u, &q, &k, &v);
        for step in 0..steps {
            let (q1, k1, v1) = (
                rand_mat(&mut rng, 1, d),
                rand_mat(&mut rng, 1, d),
                rand_mat(&mut rng, 1, d),
            );
            let a = fused.decode_step(&mut st_f, &q1, &k1, &v1);
            let b = plain.decode_step(&mut st_u, &q1, &k1, &v1);
            let cos = cosine_similarity(a.as_slice(), b.as_slice());
            assert!(
                cos >= 0.999,
                "{} step {step}: fused vs unfused cos={cos}",
                kind.name()
            );
            assert!(a.as_slice().iter().all(|x| x.is_finite()), "{}", kind.name());
        }
        // The toggle only changes how the attention row is computed — the
        // resident K/V states advance through the identical append path.
        assert_eq!(
            state_fingerprint(&st_f),
            state_fingerprint(&st_u),
            "{}: fused decode must leave the same resident state",
            kind.name()
        );
    }
}

#[test]
fn fused_decode_single_key_history_is_byte_exact_for_index_softmax() {
    // Degenerate case where the two rounding schedules coincide: a decode
    // step over a single-key history has exactly one probability, which
    // both paths represent exactly (Ê = ΣÊ ⇒ P̂ = 255 and the fused final
    // normalize reproduces the same integer), so IndexSoftmax outputs are
    // byte-equal — including under grouped-Q quantization. (EXAQ's fused
    // form normalizes in float and differs by final-rescale ulps even
    // here, so it is covered by the cosine bound above instead.)
    let d = 16;
    let mut rng = Pcg64::seed_from_u64(2100);
    let (q1, k1, v1) =
        (rand_mat(&mut rng, 1, d), rand_mat(&mut rng, 1, d), rand_mat(&mut rng, 1, d));
    for scheme in [None, Some(GroupScheme::PerRow)] {
        let mk = |on: bool| {
            let p = IntAttention::new(AttentionConfig::new(0, d).with_fused_decode(on));
            match scheme {
                Some(s) => p.with_q_scheme(s),
                None => p,
            }
        };
        let (mut fused, mut plain) = (mk(true), mk(false));
        let mut st_f = fused.begin_state();
        let mut st_u = plain.begin_state();
        let a = fused.decode_step(&mut st_f, &q1, &k1, &v1);
        let b = plain.decode_step(&mut st_u, &q1, &k1, &v1);
        assert_eq!(a.as_slice(), b.as_slice(), "scheme {scheme:?}");
    }
}
