//! Model-level integration: trained-artifact loading, decode/prefill parity,
//! and cross-pipeline perplexity ordering on the tiny LM.

use intattention::attention::PipelineKind;
use intattention::harness::experiments::load_or_random_weights;
use intattention::harness::fidelity::{eval_lm_fidelity, eval_sequences};
use intattention::model::config::ModelConfig;
use intattention::model::lm::{KvCache, TinyLm};
use intattention::model::weights::Weights;
use intattention::util::prng::Pcg64;

#[test]
fn trained_weights_load_if_present() {
    let dir = intattention::runtime::default_artifacts_dir();
    if !dir.join("weights.bin").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = Weights::load(&dir).expect("trained weights parse");
    assert_eq!(w.cfg.vocab, 256);
    assert_eq!(w.to_flat().len(), w.cfg.param_count());
    // A trained model must beat chance perplexity (vocab=256) massively.
    let seqs = eval_sequences(&dir, 4, 128, w.cfg.vocab);
    let f = eval_lm_fidelity(&w, PipelineKind::Fp32, &seqs);
    assert!(f.perplexity < 16.0, "trained ppl {} too high", f.perplexity);
}

#[test]
fn pipeline_perplexity_ordering_matches_table1_shape() {
    let dir = intattention::runtime::default_artifacts_dir();
    if !dir.join("weights.bin").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = load_or_random_weights();
    let seqs = eval_sequences(&dir, 4, 128, w.cfg.vocab);
    let fp32 = eval_lm_fidelity(&w, PipelineKind::Fp32, &seqs);
    let ia = eval_lm_fidelity(&w, PipelineKind::IntAttention, &seqs);
    let ex2 = eval_lm_fidelity(&w, PipelineKind::ExaqInt2, &seqs);
    // IntAttention stays close to FP32 (paper: within ~5% ppl)…
    assert!(
        ia.perplexity < fp32.perplexity * 1.15,
        "IntAttention ppl {} vs FP32 {}",
        ia.perplexity,
        fp32.perplexity
    );
    // …and EXAQ-INT2 degrades more than IntAttention (Table 5 shape).
    assert!(
        ex2.loss_mad > ia.loss_mad,
        "EXAQ2 mad {} !> IntAttention mad {}",
        ex2.loss_mad,
        ia.loss_mad
    );
}

#[test]
fn decode_matches_prefill_for_every_pipeline() {
    let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 2, n_heads: 2, max_seq: 32, mlp_mult: 2 };
    let w = Weights::random(cfg, 9);
    let tokens = [3u16, 7, 1, 20, 4, 9, 30, 2];
    for kind in [PipelineKind::Fp32, PipelineKind::IntAttention] {
        let mut lm = TinyLm::new(w.clone(), kind);
        let mut cache = KvCache::new(2, 16);
        let _ = lm.forward(&tokens[..7], Some(&mut cache));
        let inc = lm.decode_step(tokens[7], &mut cache);
        let full = lm.forward(&tokens, None);
        let last = full.row(7);
        // FP32 is numerically tight; the integer pipeline re-quantizes a
        // slightly different tensor (cache layout) so allow a loose band.
        let tol = if kind == PipelineKind::Fp32 { 1e-4 } else { 0.6 };
        for (a, b) in inc.row(0).iter().zip(last) {
            assert!((a - b).abs() < tol, "{}: {a} vs {b}", kind.name());
        }
    }
}

#[test]
fn generation_is_deterministic_given_seed() {
    let cfg = ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 48, mlp_mult: 2 };
    let w = Weights::random(cfg, 10);
    let mut lm = TinyLm::new(w, PipelineKind::IntAttention);
    let mut r1 = Pcg64::seed_from_u64(5);
    let mut r2 = Pcg64::seed_from_u64(5);
    let a = lm.generate(&[1, 2, 3], 10, 0.9, 8, &mut r1);
    let b = lm.generate(&[1, 2, 3], 10, 0.9, 8, &mut r2);
    assert_eq!(a, b);
}
