//! Property test for the fused flash-decode path: interleave single-call
//! batched decode with everything that mutates or aliases resident KV state
//! — running-scale re-maps, copy-on-write shared prefixes, ragged batch
//! widths — across every integer `PipelineKind`, grouped-Q quantization and
//! page sizes 1/2/64, and hold the fused path to its two contracts:
//!
//! 1. **Page-size invariance, bit-for-bit.** The fused walk renormalizes
//!    per element with a sequential per-sequence walk, so page boundaries
//!    are pure layout: the same schedule at page sizes 1, 2 and 64 must
//!    produce byte-identical outputs.
//! 2. **Fidelity to the unfused oracle.** Quant-Only ignores the toggle
//!    (byte-equal by construction); the IndexSoftmax/EXAQ fused forms are
//!    ε-bounded against `fused_decode(false)` (see the documented rounding
//!    contract in `attention::int_attention`), asserted as per-round
//!    cosine ≥ 0.999.
//!
//! The allocation-accounting side of the acceptance criterion (no L-length
//! row materialized per step) lives in `tests/decode_alloc.rs`.

use intattention::attention::int_attention::IntAttention;
use intattention::attention::{
    build_pipeline, AttentionConfig, AttentionPipeline, KvState, PipelineKind,
};
use intattention::quant::GroupScheme;
use intattention::tensor::MatF32;
use intattention::util::prng::Pcg64;
use intattention::util::stats::cosine_similarity;

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

fn make(
    kind: PipelineKind,
    scheme: Option<GroupScheme>,
    cfg: AttentionConfig,
) -> Box<dyn AttentionPipeline> {
    match scheme {
        Some(s) => Box::new(IntAttention::new(cfg).with_q_scheme(s)),
        None => build_pipeline(kind, cfg),
    }
}

/// One deterministic serving schedule: a donor prefilled with ramping
/// magnitudes (re-scales fire during prefill), two CoW adopters sharing its
/// prefix at a page-aligned and a mid-page boundary, one fresh short state —
/// then six batched decode rounds over shrinking (ragged) batch widths with
/// two magnitude spikes that force the running-scale remap to rewrite (and
/// CoW-fork) resident history mid-run. Returns the concatenated outputs.
fn run_schedule(
    kind: PipelineKind,
    scheme: Option<GroupScheme>,
    fused: bool,
    page_rows: usize,
    d: usize,
    split: usize,
) -> Vec<f32> {
    let mut rng = Pcg64::seed_from_u64(42);
    let mut pipe = make(
        kind,
        scheme,
        AttentionConfig::new(0, d).with_fused_decode(fused).with_decode_split(split),
    );

    // Donor prefix with ramping K/V magnitudes: the running abs-max grows
    // repeatedly, so the INT8 re-scale remap runs during prefill too.
    let prefix = 12usize;
    let q = rand_mat(&mut rng, prefix, d);
    let mut k = rand_mat(&mut rng, prefix, d);
    let mut v = rand_mat(&mut rng, prefix, d);
    for r in 0..prefix {
        let gain = 1.0 + r as f32 * 0.3;
        for x in k.row_mut(r).iter_mut().chain(v.row_mut(r)) {
            *x *= gain;
        }
    }
    let mut donor = KvState::with_page_rows(kind, d, page_rows);
    let _ = pipe.prefill(&mut donor, &q, &k, &v);

    // CoW adopters: row 8 is page-aligned for sizes 1/2 and mid-page for
    // 64; row 5 is mid-page for 2 and 64 — both tail-fork paths run.
    let mut adopter_a = donor.share_prefix(8);
    let mut adopter_b = donor.share_prefix(5);
    assert!(adopter_a.shared_pages() > 0, "{}: adoption must alias pages", kind.name());

    let mut fresh = KvState::with_page_rows(kind, d, page_rows);
    let fq = rand_mat(&mut rng, 3, d);
    let fk = rand_mat(&mut rng, 3, d);
    let fv = rand_mat(&mut rng, 3, d);
    let _ = pipe.prefill(&mut fresh, &fq, &fk, &fv);

    let mut states = [donor, adopter_a, adopter_b, fresh];
    let widths = [4usize, 4, 4, 3, 3, 2]; // ragged: trailing states sit rounds out
    let mut out = Vec::new();
    for (round, &w) in widths.iter().enumerate() {
        let qr = rand_mat(&mut rng, w, d);
        let mut kr = rand_mat(&mut rng, w, d);
        let mut vr = rand_mat(&mut rng, w, d);
        if round == 2 || round == 4 {
            // Magnitude spike: grows every running abs-max, forcing the
            // op-counted remap over resident (partly shared) pages.
            for x in kr.as_mut_slice().iter_mut().chain(vr.as_mut_slice()) {
                *x *= 8.0;
            }
        }
        let mut refs: Vec<&mut KvState> = states[..w].iter_mut().collect();
        let o = pipe.decode_step_batch(&mut refs, &qr, &kr, &vr);
        assert!(o.as_slice().iter().all(|x| x.is_finite()), "{} round {round}", kind.name());
        out.extend_from_slice(o.as_slice());
    }
    // The spikes must actually have exercised the re-scale path.
    assert!(
        states[0].as_int8().k.rescales > 0,
        "{}: schedule must trigger re-scale remaps",
        kind.name()
    );
    out
}

#[test]
fn fused_decode_page_invariant_and_faithful_under_remaps_sharing_and_ragged_batches() {
    let d = 16;
    let cases = [
        (PipelineKind::QuantOnly, None),
        (PipelineKind::IntAttention, None),
        (PipelineKind::IntAttention, Some(GroupScheme::PerRow)),
        (PipelineKind::ExaqInt2, None),
        (PipelineKind::ExaqInt3, None),
    ];
    // Under Miri one fused kind and one page-boundary pair keep the
    // UB-checking pass tractable while still walking every code path of
    // the schedule (remaps, CoW forks, ragged batches).
    let cases: &[(PipelineKind, Option<GroupScheme>)] =
        if cfg!(miri) { &cases[..2] } else { &cases };
    let page_list: &[usize] = if cfg!(miri) { &[1, 2] } else { &[1, 2, 64] };
    for &(kind, scheme) in cases {
        let mut fused_outs: Vec<Vec<f32>> = Vec::new();
        for &page_rows in page_list {
            let f = run_schedule(kind, scheme, true, page_rows, d, 1);
            let u = run_schedule(kind, scheme, false, page_rows, d, 1);
            assert_eq!(f.len(), u.len());
            if kind == PipelineKind::QuantOnly {
                // No fused form: the toggle must be a no-op.
                assert_eq!(f, u, "QuantOnly page {page_rows}: toggle must not change outputs");
            } else {
                let cos = cosine_similarity(&f, &u);
                assert!(
                    cos >= 0.999,
                    "{} {scheme:?} page {page_rows}: fused vs unfused cos={cos}",
                    kind.name()
                );
            }
            fused_outs.push(f);
        }
        // Contract 1: the fused walk is pure layout over pages.
        for (f, &p) in fused_outs.iter().zip(page_list).skip(1) {
            assert_eq!(
                &fused_outs[0], f,
                "{} {scheme:?}: fused output must be byte-identical at page sizes 1 vs {p}",
                kind.name()
            );
        }
    }
}

/// Contract 3 (page-parallel spans): the split width is pure schedule. The
/// same serving schedule — re-scale remaps, CoW shared prefixes, ragged
/// batches — run at split widths 1/2/4/8 (and auto) must produce
/// **byte-identical** outputs for every integer kind at every page size:
/// the two-phase walk's partials are associative integer sums, so where the
/// page list is cut (and how many workers gather) can never show up in the
/// output.
#[test]
fn fused_decode_split_width_is_pure_schedule() {
    let d = 16;
    let kinds = [PipelineKind::IntAttention, PipelineKind::ExaqInt2, PipelineKind::ExaqInt3];
    let kinds: &[PipelineKind] = if cfg!(miri) { &kinds[..1] } else { &kinds };
    let page_list: &[usize] = if cfg!(miri) { &[2] } else { &[1, 2, 64] };
    let splits: &[usize] = if cfg!(miri) { &[2, 4] } else { &[2, 4, 8, 0] };
    for &kind in kinds {
        for &page_rows in page_list {
            let base = run_schedule(kind, None, true, page_rows, d, 1);
            for &split in splits {
                let got = run_schedule(kind, None, true, page_rows, d, split);
                assert_eq!(
                    base, got,
                    "{} page {page_rows} split {split}: split width leaked into the output",
                    kind.name()
                );
            }
        }
    }
}
