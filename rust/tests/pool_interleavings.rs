//! Exhaustive two-thread interleaving check of the `ParallelPool` shutdown
//! protocol (loom-style model checking, hand-rolled — the offline image has
//! no `loom`).
//!
//! The protocol under test is the one `ParallelPool`'s `Drop` impl and the
//! worker loop implement (`src/util/threadpool.rs`):
//!
//! * **worker**: lock the queue mutex → (queue empty) check `shutdown` →
//!   exit if set, else `Condvar::wait` (atomically unlock + park) → on
//!   wakeup reacquire and re-check;
//! * **dropper**: lock the queue mutex → store `shutdown = true` →
//!   unlock → `notify_all` → join.
//!
//! The load-bearing detail is that the store happens **while holding the
//! mutex**. A dropper that stores and notifies without the lock can race
//! into the window between the worker's `shutdown` check and its `wait`:
//! the notify finds nobody parked and is lost, the worker then parks
//! forever, and the join deadlocks. These tests enumerate *every*
//! interleaving of both variants and assert the correct protocol has no
//! deadlock while the buggy one provably does — so a future refactor that
//! "simplifies" the store out from under the lock fails CI here, not
//! occasionally in production.
//!
//! The model gives each thread a program counter over atomic steps
//! (mutex acquire, flag store, condvar wait/notify are each one step —
//! matching the real primitives' atomicity) and DFS-explores every
//! scheduler choice. State space: a handful of PCs × lock × flag — tiny,
//! so exhaustiveness is cheap even under Miri.

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Worker {
    /// Wants the queue mutex (initial state, and after a wakeup).
    Acquire,
    /// Holds the mutex; about to check the shutdown flag.
    Check,
    /// Read `shutdown == false`; still holds the mutex, about to enter
    /// `Condvar::wait`. This read→park window is the race the locked
    /// store closes: while the worker sits here the mutex is held, so a
    /// store that needs the mutex cannot land in between — an unlocked
    /// store can.
    AboutToWait,
    /// Parked in `Condvar::wait` (mutex released). Not runnable.
    Parked,
    /// Notified; wants to reacquire the mutex to re-check.
    Reacquire,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Dropper {
    /// Correct variant only: acquire the mutex before the store.
    Lock,
    /// Store `shutdown = true` (under the mutex iff `Lock` ran).
    Store,
    /// Correct variant only: release the mutex.
    Unlock,
    /// `Condvar::notify_all` — wakes the worker iff it is parked *now*.
    Notify,
    /// `JoinHandle::join` — runnable only once the worker is `Done`.
    Join,
    Done,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
struct State {
    w: Worker,
    d: Dropper,
    /// Which thread holds the queue mutex.
    lock: Option<Thread>,
    shutdown: bool,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Thread {
    W,
    D,
}

impl State {
    fn initial(locked_store: bool) -> State {
        State {
            w: Worker::Acquire,
            d: if locked_store { Dropper::Lock } else { Dropper::Store },
            lock: None,
            shutdown: false,
        }
    }

    fn worker_runnable(&self) -> bool {
        match self.w {
            Worker::Acquire | Worker::Reacquire => self.lock.is_none(),
            Worker::Check | Worker::AboutToWait => true,
            Worker::Parked | Worker::Done => false,
        }
    }

    fn dropper_runnable(&self) -> bool {
        match self.d {
            Dropper::Lock => self.lock.is_none(),
            Dropper::Store | Dropper::Unlock | Dropper::Notify => true,
            Dropper::Join => self.w == Worker::Done,
            Dropper::Done => false,
        }
    }

    fn step_worker(mut self) -> State {
        match self.w {
            Worker::Acquire | Worker::Reacquire => {
                debug_assert!(self.lock.is_none());
                self.lock = Some(Thread::W);
                self.w = Worker::Check;
            }
            Worker::Check => {
                debug_assert_eq!(self.lock, Some(Thread::W));
                if self.shutdown {
                    self.lock = None;
                    self.w = Worker::Done;
                } else {
                    // Flag read and park are distinct instructions in the
                    // real loop; the mutex stays held across the gap.
                    self.w = Worker::AboutToWait;
                }
            }
            Worker::AboutToWait => {
                // `Condvar::wait`: release + park is one atomic step —
                // the guarantee the real condvar provides.
                debug_assert_eq!(self.lock, Some(Thread::W));
                self.lock = None;
                self.w = Worker::Parked;
            }
            Worker::Parked | Worker::Done => unreachable!("not runnable"),
        }
        self
    }

    fn step_dropper(mut self) -> State {
        match self.d {
            Dropper::Lock => {
                debug_assert!(self.lock.is_none());
                self.lock = Some(Thread::D);
                self.d = Dropper::Store;
            }
            Dropper::Store => {
                self.shutdown = true;
                self.d = if self.lock == Some(Thread::D) { Dropper::Unlock } else { Dropper::Notify };
            }
            Dropper::Unlock => {
                debug_assert_eq!(self.lock, Some(Thread::D));
                self.lock = None;
                self.d = Dropper::Notify;
            }
            Dropper::Notify => {
                if self.w == Worker::Parked {
                    self.w = Worker::Reacquire;
                }
                self.d = Dropper::Join;
            }
            Dropper::Join => {
                debug_assert_eq!(self.w, Worker::Done);
                self.d = Dropper::Done;
            }
            Dropper::Done => unreachable!("not runnable"),
        }
        self
    }
}

/// DFS every scheduler choice from `s`. Returns the number of complete
/// interleavings explored and pushes any deadlock state found.
fn explore(s: State, traces: &mut u64, deadlocks: &mut Vec<State>, depth: usize) {
    // Longest possible trace is ~10 steps; a generous bound turns any
    // modeling mistake into a loud failure instead of a hang.
    assert!(depth < 64, "model does not terminate: {s:?}");
    if s.w == Worker::Done && s.d == Dropper::Done {
        *traces += 1;
        return;
    }
    let wr = s.worker_runnable();
    let dr = s.dropper_runnable();
    if !wr && !dr {
        deadlocks.push(s);
        return;
    }
    if wr {
        explore(s.step_worker(), traces, deadlocks, depth + 1);
    }
    if dr {
        explore(s.step_dropper(), traces, deadlocks, depth + 1);
    }
}

#[test]
fn locked_shutdown_store_terminates_in_every_interleaving() {
    let mut traces = 0;
    let mut deadlocks = Vec::new();
    explore(State::initial(true), &mut traces, &mut deadlocks, 0);
    assert!(traces > 0);
    assert!(
        deadlocks.is_empty(),
        "shutdown-under-mutex must never lose the wakeup, but: {deadlocks:?}"
    );
}

#[test]
fn unlocked_shutdown_store_has_a_lost_wakeup_interleaving() {
    let mut traces = 0;
    let mut deadlocks = Vec::new();
    explore(State::initial(false), &mut traces, &mut deadlocks, 0);
    // The bug is real: some schedules do finish, but at least one parks the
    // worker after the notify already fired and the join never returns.
    assert!(traces > 0, "some interleavings still complete");
    assert!(
        !deadlocks.is_empty(),
        "the unlocked store is expected to admit a lost wakeup — if this \
         starts passing, the model no longer matches the real protocol"
    );
    for s in &deadlocks {
        assert_eq!(s.w, Worker::Parked, "deadlock must be the parked worker: {s:?}");
        assert_eq!(s.d, Dropper::Join, "…with the dropper stuck joining: {s:?}");
    }
}
