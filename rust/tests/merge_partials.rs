//! Property test for the page-parallel fused-decode **merge operator**: for
//! random logit streams, any contiguous partition of the KV page walk,
//! combined in any associative order (left fold, right fold, balanced
//! tree), must be **byte-identical** to the sequential two-phase walk —
//! IndexSoftmax exactly (`ΣÊ`, nnz and every i64 accumulator lane), EXAQ
//! exactly on its bucketed integer state (lane sums, bucket counts, exact
//! Δ-moments, and the final `fsum` float bit pattern). Page sizes 1/2/64 ×
//! split widths 1/2/4/8, L chosen so every split is ragged.
//!
//! The general unequal-max form of [`OnlineIndexRow::merge`] (spans that
//! ran their own max phases, combined via the `rescale_lane_i64` carry) is
//! LUT-quantized and only ε-accurate — covered here by its algebraic
//! contracts: identity on unstarted states, the merged max is the global
//! max, nnz adds, and the result stays close to the pinned-max walk.
//!
//! End-to-end split invariance at pipeline level (CoW prefixes, remaps,
//! every integer `PipelineKind`) lives in `tests/fused_decode.rs`.

use intattention::gemm::{
    fused_decode_exaq, fused_decode_exaq_gather, fused_decode_exaq_max, fused_decode_i8,
    fused_decode_i8_gather, fused_decode_i8_max,
};
use intattention::softmax::exaq::{ExaqConfig, ExaqOnlineRow, ExaqSoftmax};
use intattention::softmax::index_softmax::{IndexSoftmax, OnlineIndexRow};
use intattention::util::prng::Pcg64;

const D: usize = 8;
const K: usize = 16;

fn rand_rows(rng: &mut Pcg64, rows: usize, width: usize) -> Vec<i8> {
    (0..rows * width).map(|_| rng.range_i64(-127, 128) as i8).collect()
}

/// Split a contiguous `rows×width` buffer into pages of at most
/// `rows_per_page` whole rows (the layout `PagedRows` hands the kernels).
fn split_pages<T>(buf: &[T], width: usize, rows_per_page: usize) -> Vec<&[T]> {
    assert_eq!(buf.len() % width, 0);
    buf.chunks(rows_per_page.max(1) * width).collect()
}

/// Balanced contiguous partition of a page list into `w.min(len)` spans.
fn partition<'a>(pages: &'a [&'a [i8]], w: usize) -> Vec<&'a [&'a [i8]]> {
    let n = w.min(pages.len()).max(1);
    let (base, extra) = (pages.len() / n, pages.len() % n);
    let mut out = Vec::with_capacity(n);
    let mut at = 0;
    for s in 0..n {
        let take = base + usize::from(s < extra);
        out.push(&pages[at..at + take]);
        at += take;
    }
    assert_eq!(at, pages.len());
    out
}

// --------------------------- IndexSoftmax ---------------------------

#[derive(Clone)]
struct PartI8 {
    row: OnlineIndexRow,
    acc: Vec<i64>,
}

fn merge_i8(mut a: PartI8, b: &PartI8, table: &[u8]) -> PartI8 {
    a.row.merge(&b.row, &mut a.acc, &b.acc, table);
    a
}

fn tree_merge_i8(parts: &[PartI8], table: &[u8]) -> PartI8 {
    if parts.len() == 1 {
        return parts[0].clone();
    }
    let mid = parts.len() / 2;
    let left = tree_merge_i8(&parts[..mid], table);
    let right = tree_merge_i8(&parts[mid..], table);
    merge_i8(left, &right, table)
}

/// Run the split walk: per-span max phases, max folds, rebroadcast, per-span
/// gathers — returning the unmerged partials (the span drivers' state just
/// before the combine).
fn partials_i8(
    sx: &IndexSoftmax,
    alpha: f32,
    q: &[i8],
    kp: &[&[i8]],
    vp: &[&[i8]],
    w: usize,
    tile: &mut [i32],
) -> Vec<PartI8> {
    let table = &sx.lut.u8_table;
    let kspans = partition(kp, w);
    let vspans = partition(vp, w);
    let mut rows: Vec<OnlineIndexRow> = kspans
        .iter()
        .map(|span| {
            let mut row = sx.online_begin(alpha);
            fused_decode_i8_max(q, span, &mut row, tile);
            row
        })
        .collect();
    let mut root = rows[0];
    for r in &rows[1..] {
        root.merge_max(r);
    }
    for r in rows.iter_mut() {
        *r = root;
    }
    kspans
        .iter()
        .zip(&vspans)
        .zip(rows)
        .map(|((ks, vs), mut row)| {
            let mut acc = vec![0i64; D];
            fused_decode_i8_gather(q, ks, vs, &mut row, table, &mut acc, tile);
            PartI8 { row, acc }
        })
        .collect()
}

#[test]
fn index_softmax_partition_merges_byte_identical_in_any_order() {
    let mut rng = Pcg64::seed_from_u64(7);
    let l = if cfg!(miri) { 19 } else { 37 };
    let page_list: &[usize] = if cfg!(miri) { &[1, 2] } else { &[1, 2, 64] };
    let splits: &[usize] = if cfg!(miri) { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let sx = IndexSoftmax::default();
    let table = &sx.lut.u8_table;
    for trial in 0..if cfg!(miri) { 2 } else { 8 } {
        let alpha = 0.004 + 0.003 * trial as f32;
        let q: Vec<i8> = rand_rows(&mut rng, 1, K);
        let kbuf = rand_rows(&mut rng, l, K);
        let vbuf = rand_rows(&mut rng, l, D);
        for &pr in page_list {
            let kp = split_pages(&kbuf, K, pr);
            let vp = split_pages(&vbuf, D, pr);
            let mut tile = vec![0i32; pr.min(l)];

            let mut seq_row = sx.online_begin(alpha);
            let mut seq_acc = vec![0i64; D];
            fused_decode_i8(&q, &kp, &vp, &mut seq_row, table, &mut seq_acc, &mut tile);

            for &w in splits {
                let parts = partials_i8(&sx, alpha, &q, &kp, &vp, w, &mut tile);
                // Left fold, right fold, balanced tree: same bytes.
                let left = parts[1..]
                    .iter()
                    .fold(parts[0].clone(), |a, b| merge_i8(a, b, table));
                let right = parts[..parts.len() - 1]
                    .iter()
                    .rev()
                    .fold(parts[parts.len() - 1].clone(), |a, b| merge_i8(a, b, table));
                let tree = tree_merge_i8(&parts, table);
                for (name, got) in [("left", &left), ("right", &right), ("tree", &tree)] {
                    assert_eq!(
                        got.acc, seq_acc,
                        "trial {trial} page {pr} split {w} {name}: accumulator lanes"
                    );
                    assert_eq!(got.row.esum(), seq_row.esum(), "trial {trial} page {pr} split {w} {name}");
                    assert_eq!(got.row.nnz(), seq_row.nnz(), "trial {trial} page {pr} split {w} {name}");
                }
            }
        }
    }
}

/// The general (unequal-max) merge form: spans that ran their own max
/// phases. LUT-quantized carry — ε-accurate, plus exact algebraic edges.
#[test]
fn index_softmax_general_merge_algebra() {
    let mut rng = Pcg64::seed_from_u64(11);
    let l = 24;
    let alpha = 0.01f32;
    let sx = IndexSoftmax::default();
    let table = &sx.lut.u8_table;
    let q: Vec<i8> = rand_rows(&mut rng, 1, K);
    let kbuf = rand_rows(&mut rng, l, K);
    let vbuf = rand_rows(&mut rng, l, D);
    let kp = split_pages(&kbuf, K, 2);
    let vp = split_pages(&vbuf, D, 2);
    let mut tile = vec![0i32; 2];

    // Sequential single-max oracle.
    let mut seq_row = sx.online_begin(alpha);
    let mut seq_acc = vec![0i64; D];
    fused_decode_i8(&q, &kp, &vp, &mut seq_row, table, &mut seq_acc, &mut tile);

    // Two spans, each a full independent walk against its own span max.
    let kspans = partition(&kp, 2);
    let vspans = partition(&vp, 2);
    let mut parts: Vec<PartI8> = kspans
        .iter()
        .zip(&vspans)
        .map(|(ks, vs)| {
            let mut row = sx.online_begin(alpha);
            let mut acc = vec![0i64; D];
            fused_decode_i8(&q, ks, vs, &mut row, table, &mut acc, &mut tile);
            PartI8 { row, acc }
        })
        .collect();

    // Merging an unstarted row is an identity; merging into one copies.
    let empty = sx.online_begin(alpha);
    let before = parts[0].clone();
    let merged = merge_i8(before.clone(), &PartI8 { row: empty, acc: vec![0; D] }, table);
    assert_eq!(merged.acc, before.acc);
    assert_eq!(merged.row.esum(), before.row.esum());
    let adopted = merge_i8(PartI8 { row: empty, acc: vec![0; D] }, &before, table);
    assert_eq!(adopted.acc, before.acc);
    assert_eq!(adopted.row.esum(), before.row.esum());

    // The general carry: merged state tracks the pinned-max walk closely
    // (the carry factor is LUT-quantized, so not bit-exact in general).
    let b = parts.pop().unwrap();
    let a = parts.pop().unwrap();
    let nnz_sum = a.row.nnz() + b.row.nnz();
    let g = merge_i8(a, &b, table);
    assert_eq!(g.row.nnz(), nnz_sum, "nnz adds regardless of carry");
    let dot: f64 = g.acc.iter().zip(&seq_acc).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = g.acc.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = seq_acc.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(dot / (na * nb) > 0.99, "general merge strays from the oracle");
    let rel = (g.row.esum() as f64 - seq_row.esum() as f64).abs() / seq_row.esum() as f64;
    assert!(rel < 0.05, "ΣÊ relative error {rel}");
}

// ------------------------------- EXAQ -------------------------------

#[derive(Clone)]
struct PartExaq {
    row: ExaqOnlineRow,
    acc: Vec<i64>,
}

fn merge_exaq(mut a: PartExaq, b: &PartExaq) -> PartExaq {
    a.row.merge(&b.row);
    for (x, &y) in a.acc.iter_mut().zip(&b.acc) {
        *x += y;
    }
    a
}

fn tree_merge_exaq(parts: &[PartExaq]) -> PartExaq {
    if parts.len() == 1 {
        return parts[0].clone();
    }
    let mid = parts.len() / 2;
    merge_exaq(tree_merge_exaq(&parts[..mid]), &tree_merge_exaq(&parts[mid..]))
}

#[test]
fn exaq_partition_merges_byte_identical_in_any_order() {
    let mut rng = Pcg64::seed_from_u64(23);
    let l = if cfg!(miri) { 19 } else { 37 };
    let page_list: &[usize] = if cfg!(miri) { &[1, 2] } else { &[1, 2, 64] };
    let splits: &[usize] = if cfg!(miri) { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    for (bits, clip) in [(2u32, 2.5f32), (3, 4.0)] {
        let sx = ExaqSoftmax::new(if bits == 2 { ExaqConfig::int2() } else { ExaqConfig::int3() });
        let entries = sx.entries();
        let alpha = 0.02f32;
        let lut = sx.lut_f32(clip);
        let q: Vec<i8> = rand_rows(&mut rng, 1, K);
        let kbuf = rand_rows(&mut rng, l, K);
        let vbuf = rand_rows(&mut rng, l, D);
        for &pr in page_list {
            let kp = split_pages(&kbuf, K, pr);
            let vp = split_pages(&vbuf, D, pr);
            let mut tile = vec![0i32; pr.min(l)];

            let mut seq_row = sx.online_begin(alpha, clip);
            let mut seq_acc = vec![0i64; entries * D];
            fused_decode_exaq(&q, &kp, &vp, &mut seq_row, &mut seq_acc, &mut tile);

            for &w in splits {
                let kspans = partition(&kp, w);
                let vspans = partition(&vp, w);
                let mut rows: Vec<ExaqOnlineRow> = kspans
                    .iter()
                    .map(|span| {
                        let mut row = sx.online_begin(alpha, clip);
                        fused_decode_exaq_max(&q, span, &mut row, &mut tile);
                        row
                    })
                    .collect();
                let mut root = rows[0];
                for r in &rows[1..] {
                    root.merge_max(r);
                }
                for r in rows.iter_mut() {
                    *r = root;
                }
                let parts: Vec<PartExaq> = kspans
                    .iter()
                    .zip(&vspans)
                    .zip(rows)
                    .map(|((ks, vs), mut row)| {
                        let mut acc = vec![0i64; entries * D];
                        fused_decode_exaq_gather(&q, ks, vs, &mut row, &mut acc, &mut tile);
                        PartExaq { row, acc }
                    })
                    .collect();
                let left = parts[1..].iter().fold(parts[0].clone(), |a, b| merge_exaq(a, b));
                let right = parts[..parts.len() - 1]
                    .iter()
                    .rev()
                    .fold(parts[parts.len() - 1].clone(), |a, b| merge_exaq(a, b));
                let tree = tree_merge_exaq(&parts);
                for (name, got) in [("left", &left), ("right", &right), ("tree", &tree)] {
                    assert_eq!(
                        got.acc, seq_acc,
                        "int{bits} page {pr} split {w} {name}: bucket lanes"
                    );
                    assert_eq!(got.row.counts(), seq_row.counts(), "int{bits} page {pr} split {w} {name}");
                    assert_eq!(got.row.nnz(), seq_row.nnz(), "int{bits} page {pr} split {w} {name}");
                    assert_eq!(
                        got.row.fsum(&lut).to_bits(),
                        seq_row.fsum(&lut).to_bits(),
                        "int{bits} page {pr} split {w} {name}: fsum bits"
                    );
                    let (gs, gq, gn) = got.row.stats(alpha);
                    let (ss, sq, sn) = seq_row.stats(alpha);
                    assert_eq!((gs.to_bits(), gq.to_bits(), gn), (ss.to_bits(), sq.to_bits(), sn));
                }
            }
        }
    }
}
