//! TCP front-end integration: a real engine behind a real
//! [`intattention::coordinator::tcp::TcpServer`] on an ephemeral port,
//! driven by real sockets. Asserts the wire stream mirrors the in-process
//! event grammar (QUEUED, PREFILLING, sequential TOKENs, one terminal
//! FINAL), that rejects surface as REJECTED frames, and that the CANCEL
//! verb terminates a stream inside the grammar.

use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::tcp::{
    read_frame, run_client, write_frame, ClientMsg, ServerMsg, TcpServer,
};
use intattention::coordinator::{Engine, EngineHandle, EngineOptions, SubmitOptions};
use intattention::model::config::ModelConfig;
use intattention::model::weights::Weights;
use std::net::TcpStream;
use std::sync::Arc;

fn engine() -> Arc<EngineHandle> {
    let cfg =
        ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
    let opts = EngineOptions {
        policy: BatchPolicy { max_active: 4, ..Default::default() },
        ..Default::default()
    };
    Arc::new(Engine::start(Weights::random(cfg, 37), opts))
}

/// Stop the server, then recover and shut down the engine it was holding.
fn teardown(server: TcpServer, engine: Arc<EngineHandle>) {
    server.stop();
    Arc::try_unwrap(engine).ok().expect("server released the engine").shutdown();
}

#[test]
fn streamed_request_over_tcp_matches_the_wire_grammar() {
    let engine = engine();
    let server = TcpServer::spawn(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let gen = 5usize;
    let events = run_client(&addr, &[1, 2, 3, 4], gen, SubmitOptions::default()).unwrap();
    assert!(events.len() >= 3, "expected at least QUEUED/PREFILLING/FINAL, got {events:?}");
    assert!(matches!(events[0], ServerMsg::Queued { tag: 1, .. }), "first frame: {:?}", events[0]);
    assert!(
        matches!(events[1], ServerMsg::Prefilling { tag: 1, .. }),
        "second frame: {:?}",
        events[1]
    );
    let mut streamed = Vec::new();
    for (k, ev) in events[2..events.len() - 1].iter().enumerate() {
        match ev {
            ServerMsg::Token { tag, index, token, .. } => {
                assert_eq!(*tag, 1);
                assert_eq!(*index as usize, k, "token indexes must be sequential");
                streamed.push(*token);
            }
            other => panic!("unexpected mid-stream frame {other:?}"),
        }
    }
    match events.last().unwrap() {
        ServerMsg::Final { tag, finish, tokens, total_us, .. } => {
            assert_eq!(*tag, 1);
            assert_eq!(*finish, 0, "greedy short request finishes Done");
            assert_eq!(tokens.len(), gen);
            assert_eq!(*tokens, streamed, "FINAL tokens != streamed TOKEN frames");
            assert!(*total_us > 0);
        }
        other => panic!("stream must end with FINAL, got {other:?}"),
    }

    teardown(server, engine);
}

#[test]
fn bad_request_surfaces_as_a_rejected_frame() {
    let engine = engine();
    let server = TcpServer::spawn(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let events = run_client(&addr, &[], 2, SubmitOptions::default()).unwrap();
    let expect = vec![ServerMsg::Rejected { tag: 1, code: 0 }];
    assert_eq!(events, expect, "empty prompt must answer REJECTED(BadRequest)");

    teardown(server, engine);
}

#[test]
fn cancel_verb_terminates_the_stream_in_grammar() {
    let engine = engine();
    let server = TcpServer::spawn(Arc::clone(&engine), "127.0.0.1:0").unwrap();
    let addr = server.local_addr().to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    let submit = ClientMsg::Submit {
        tag: 7,
        gen_len: 40,
        top_k: 1,
        temp_milli: 0,
        deadline_ms: 0,
        stream_buffer: 0,
        prompt: vec![1, 2, 3],
    };
    write_frame(&mut stream, &submit.encode()).unwrap();
    // Cancel races the decode loop: the stream must still terminate with
    // exactly one FINAL, whichever side wins.
    write_frame(&mut stream, &ClientMsg::Cancel { tag: 7 }.encode()).unwrap();

    let mut finals = 0;
    let mut next_index = 0u32;
    loop {
        let body = read_frame(&mut stream).unwrap();
        let msg = ServerMsg::decode(&body).unwrap();
        assert_eq!(msg.tag(), 7, "all frames carry the submit tag");
        match msg {
            ServerMsg::Token { index, .. } => {
                assert_eq!(index, next_index, "token order survives the cancel race");
                next_index += 1;
            }
            ServerMsg::Final { finish, tokens, .. } => {
                finals += 1;
                // Done(0), Length(1) or Cancelled(2) depending on the race.
                assert!(finish <= 2, "unexpected finish code {finish}");
                assert_eq!(tokens.len() as u32, next_index);
                break;
            }
            ServerMsg::Rejected { .. } => panic!("valid submit must not be rejected"),
            ServerMsg::Queued { .. } | ServerMsg::Prefilling { .. } => {}
        }
    }
    assert_eq!(finals, 1);
    drop(stream);

    teardown(server, engine);
}
