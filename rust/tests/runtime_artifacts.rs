//! PJRT runtime round-trip over the AOT artifacts (requires `make artifacts`;
//! tests self-skip when artifacts are absent so `cargo test` stays green on
//! a fresh checkout).

use intattention::attention::{build_pipeline, AttentionConfig, PipelineKind};
use intattention::harness::workload::random_qkv;
use intattention::runtime::{default_artifacts_dir, ArtifactRuntime, PJRT_AVAILABLE};
use intattention::util::prng::Pcg64;
use intattention::util::stats::cosine_similarity;

fn runtime_or_skip() -> Option<ArtifactRuntime> {
    if !PJRT_AVAILABLE {
        eprintln!("skipping: built without the `pjrt` feature (no `xla` crate in the image)");
        return None;
    }
    let dir = default_artifacts_dir();
    if !dir.join("int_attention_head_l64_d32.hlo.txt").exists() {
        eprintln!("skipping: artifacts not built (`make artifacts`)");
        return None;
    }
    Some(ArtifactRuntime::new(&dir).expect("pjrt cpu client"))
}

#[test]
fn pallas_artifact_matches_native_rust_bit_path() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (l, d) = (64usize, 32usize);
    let mut rng = Pcg64::seed_from_u64(3);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let shape = [l, d];
    let outs = rt
        .run(
            "int_attention_head_l64_d32",
            &[(q.as_slice(), &shape), (k.as_slice(), &shape), (v.as_slice(), &shape)],
        )
        .expect("execute");
    let mut pipe = build_pipeline(PipelineKind::IntAttention, AttentionConfig::new(l, d));
    let rust_out = pipe.forward(&q, &k, &v);
    let cos = cosine_similarity(&outs[0], rust_out.as_slice());
    // Same integer arithmetic (eq. 2-15) on both sides: near-identical.
    assert!(cos > 0.999_999, "cos={cos}");
}

#[test]
fn index_softmax_artifact_normalizes_rows() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let l = 64usize;
    let mut rng = Pcg64::seed_from_u64(5);
    let logits: Vec<f32> = (0..l * l).map(|_| (rng.range_i64(-20_000, 20_001)) as f32).collect();
    let alpha = [0.002f32];
    let outs = rt
        .run(
            "index_softmax_l64",
            &[(&logits, &[l, l][..]), (&alpha, &[1usize][..])],
        )
        .expect("execute");
    let p = &outs[0];
    assert_eq!(p.len(), l * l);
    for r in 0..l {
        let s: f32 = p[r * l..(r + 1) * l].iter().sum();
        assert!((s - 1.0).abs() < 0.07, "row {r} sums to {s}");
    }
}

#[test]
fn float_oracle_artifact_matches_rust_fp32() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let (l, d) = (64usize, 32usize);
    let mut rng = Pcg64::seed_from_u64(7);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let shape = [l, d];
    let outs = rt
        .run(
            "float_attention_head_l64_d32",
            &[(q.as_slice(), &shape), (k.as_slice(), &shape), (v.as_slice(), &shape)],
        )
        .expect("execute");
    let mut pipe = build_pipeline(PipelineKind::Fp32, AttentionConfig::new(l, d));
    let rust_out = pipe.forward(&q, &k, &v);
    let cos = cosine_similarity(&outs[0], rust_out.as_slice());
    assert!(cos > 0.99999, "cos={cos}");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let err = rt.run("no_such_artifact", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("no_such_artifact"));
}
