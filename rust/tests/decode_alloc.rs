//! Allocation accounting for the decode hot path — the acceptance criterion
//! behind the fused flash-decode walk, asserted with a counting global
//! allocator: a steady-state decode step's heap traffic must not scale with
//! the resident context length.
//!
//! The fused walk's working set is O(d) accumulator + O(pages) descriptors
//! per sequence — never an L-length score row. The unfused paths DO hold
//! O(L) logit/probability rows, but in per-pipeline reusable scratch
//! (`dec_*` fields), so their steady state allocates nothing L-dependent
//! per token either. Both are held to the same invariant here: with the
//! page count pinned (one huge page), the per-step allocation minimum at a
//! 16×-larger context must match the small-context one to within a small
//! constant. A reintroduced per-step `Vec` of logits (4·L bytes) fails this
//! immediately at either context size.
//!
//! The same accounting holds the **online-tiled prefill** to its
//! acceptance criterion: a prefill block's heap traffic must not scale
//! with the resident context it attends over (no `m×L` score block),
//! while the materialized arm — kept as the oracle — demonstrably does.
//!
//! This file stays a single `#[test]`: the byte counter is process-global,
//! and sibling tests running on other threads would bleed into the
//! measurement windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use intattention::attention::{
    build_pipeline, AttentionConfig, AttentionPipeline, KvState, PipelineKind,
};
use intattention::tensor::MatF32;
use intattention::util::prng::Pcg64;

struct CountingAlloc;

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` plus a relaxed atomic counter — the
// allocator obligations (layout fidelity, no unwinding, no reentrant
// allocation) are exactly `System`'s, which the delegation preserves.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: `layout` is forwarded unmodified from our caller, who
        // upholds `GlobalAlloc::alloc`'s contract (non-zero size).
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr`/`layout` come from our caller's matching `alloc`,
        // which delegated to `System`, so they denote a live System block.
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            ALLOCATED.fetch_add((new_size - layout.size()) as u64, Ordering::Relaxed);
        }
        // SAFETY: same delegation argument as `dealloc`, and `new_size`
        // is forwarded under the caller's `realloc` contract.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated() -> u64 {
    ALLOCATED.load(Ordering::Relaxed)
}

fn rand_mat(rng: &mut Pcg64, r: usize, c: usize) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
}

/// Steady-state bytes allocated by one `decode_step`: 3 unmeasured warm
/// steps settle the reusable scratch capacity (amortized `Vec` growth),
/// then the minimum over 8 measured steps skips any remaining doubling
/// spike. Decode K/V rows are damped so the (allocating) re-scale remap
/// cannot fire inside a measurement window.
fn steady_step_bytes(
    pipe: &mut dyn AttentionPipeline,
    st: &mut KvState,
    rng: &mut Pcg64,
    d: usize,
) -> u64 {
    let mut samples = Vec::new();
    for i in 0..11 {
        let q1 = rand_mat(rng, 1, d);
        let mut k1 = rand_mat(rng, 1, d);
        let mut v1 = rand_mat(rng, 1, d);
        for x in k1.as_mut_slice().iter_mut().chain(v1.as_mut_slice()) {
            *x *= 0.5;
        }
        let before = allocated();
        let o = pipe.decode_step(st, &q1, &k1, &v1);
        let delta = allocated() - before;
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
        if i >= 3 {
            samples.push(delta);
        }
    }
    samples.into_iter().min().unwrap()
}

#[test]
fn decode_step_heap_traffic_does_not_scale_with_context() {
    let d = 32;
    // One huge page per side at every context used here: the O(pages)
    // descriptor bookkeeping is pinned, so any L-dependent allocation in
    // the step itself stands out alone.
    let page_rows = 1usize << 14;
    let (small_ctx, large_ctx) = (32usize, 512);
    let int_kinds = [
        PipelineKind::QuantOnly,
        PipelineKind::IntAttention,
        PipelineKind::ExaqInt2,
        PipelineKind::ExaqInt3,
    ];
    for fused in [true, false] {
        for kind in int_kinds {
            if fused && kind == PipelineKind::QuantOnly {
                continue; // no fused form — the toggle is a no-op there
            }
            let mut rng = Pcg64::seed_from_u64(7);
            let mut pipe =
                build_pipeline(kind, AttentionConfig::new(0, d).with_fused_decode(fused));

            let mut small = KvState::with_page_rows(kind, d, page_rows);
            let (q, k, v) = (
                rand_mat(&mut rng, small_ctx, d),
                rand_mat(&mut rng, small_ctx, d),
                rand_mat(&mut rng, small_ctx, d),
            );
            let _ = pipe.prefill(&mut small, &q, &k, &v);

            let mut large = KvState::with_page_rows(kind, d, page_rows);
            let (q, k, v) = (
                rand_mat(&mut rng, large_ctx, d),
                rand_mat(&mut rng, large_ctx, d),
                rand_mat(&mut rng, large_ctx, d),
            );
            let _ = pipe.prefill(&mut large, &q, &k, &v);

            let small_bytes = steady_step_bytes(pipe.as_mut(), &mut small, &mut rng, d);
            let large_bytes = steady_step_bytes(pipe.as_mut(), &mut large, &mut rng, d);
            assert!(
                large_bytes <= small_bytes + 64,
                "{} fused={fused}: steady decode allocates {large_bytes} B/step at ctx \
                 {large_ctx} vs {small_bytes} B/step at ctx {small_ctx} — an L-dependent \
                 buffer is being materialized per token",
                kind.name()
            );
        }
    }

    prefill_heap_traffic_does_not_scale_with_context();
}

/// Bytes allocated by one m-row prefill block against an already-resident
/// context: minimum over 6 measured blocks (K/V damped so the re-scale
/// remap cannot fire inside a window). Each block grows the context by m,
/// which is negligible against the contexts compared.
fn steady_prefill_bytes(
    pipe: &mut dyn AttentionPipeline,
    st: &mut KvState,
    rng: &mut Pcg64,
    m: usize,
    d: usize,
) -> u64 {
    let mut samples = Vec::new();
    for i in 0..8 {
        let q = rand_mat(rng, m, d);
        let mut k = rand_mat(rng, m, d);
        let mut v = rand_mat(rng, m, d);
        for x in k.as_mut_slice().iter_mut().chain(v.as_mut_slice()) {
            *x *= 0.5;
        }
        let before = allocated();
        let o = pipe.prefill(st, &q, &k, &v);
        let delta = allocated() - before;
        assert!(o.as_slice().iter().all(|x| x.is_finite()));
        if i >= 2 {
            samples.push(delta);
        }
    }
    samples.into_iter().min().unwrap()
}

/// Called from the single `#[test]` above (same process-global counter):
/// with the page count pinned, a tiled prefill block's allocation minimum
/// at a much larger resident context must match the small-context one —
/// while the materialized arm must visibly pay the `m×L` score block.
fn prefill_heap_traffic_does_not_scale_with_context() {
    let d = 32;
    let m = 8usize;
    let page_rows = 1usize << 14;
    let (small_ctx, large_ctx) = (128usize, 1024);
    for kind in [PipelineKind::IntAttention, PipelineKind::ExaqInt3] {
        for tiled in [true, false] {
            let mut rng = Pcg64::seed_from_u64(13);
            let mut pipe = build_pipeline(
                kind,
                AttentionConfig::new(0, d).with_tiled_prefill(tiled),
            );
            let mut small = KvState::with_page_rows(kind, d, page_rows);
            let (q, k, v) = (
                rand_mat(&mut rng, small_ctx, d),
                rand_mat(&mut rng, small_ctx, d),
                rand_mat(&mut rng, small_ctx, d),
            );
            let _ = pipe.prefill(&mut small, &q, &k, &v);
            let mut large = KvState::with_page_rows(kind, d, page_rows);
            let (q, k, v) = (
                rand_mat(&mut rng, large_ctx, d),
                rand_mat(&mut rng, large_ctx, d),
                rand_mat(&mut rng, large_ctx, d),
            );
            let _ = pipe.prefill(&mut large, &q, &k, &v);

            let small_bytes = steady_prefill_bytes(pipe.as_mut(), &mut small, &mut rng, m, d);
            let large_bytes = steady_prefill_bytes(pipe.as_mut(), &mut large, &mut rng, m, d);
            if tiled {
                assert!(
                    large_bytes <= small_bytes + 64,
                    "{} tiled prefill allocates {large_bytes} B/block at ctx {large_ctx} vs \
                     {small_bytes} B/block at ctx {small_ctx} — an L-dependent buffer is \
                     being materialized",
                    kind.name()
                );
            } else {
                // The materialized oracle must actually pay ≥ the m×L i32
                // logit block's growth — guards the contrast from a silent
                // no-op (e.g. the toggle wiring breaking).
                let floor = (m * (large_ctx - small_ctx) * 4) as u64;
                assert!(
                    large_bytes >= small_bytes + floor,
                    "{} materialized prefill: {large_bytes} vs {small_bytes} B/block — \
                     expected the m×L score block to grow by ≥ {floor} B",
                    kind.name()
                );
            }
        }
    }
}
