//! Stream-grammar property suite: every event stream a request observes —
//! fault-free or under chaos schedules ([`intattention::util::fault`]) —
//! must match the grammar
//!
//! ```text
//! Queued ( Prefilling Token* )? Final
//! ```
//!
//! with Token indexes strictly sequential from 0, timestamps
//! non-decreasing, **exactly one** Final as the last event, and the Final
//! response byte-identical to (and timing-consistent with) the per-token
//! events that preceded it. Whatever aborts the request — injected
//! panics, allocation failures, cancels, deadlines, drains, hard stops —
//! the stream must still terminate inside that grammar.

use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::{
    Engine, EngineOptions, FinishReason, Response, StreamEvent, StreamRx, SubmitOptions,
};
use intattention::model::config::ModelConfig;
use intattention::model::weights::Weights;
use intattention::util::fault;
use intattention::util::proptest::{check, Config};
use std::sync::{Mutex, MutexGuard, Once};
use std::time::Duration;

const LONG: Duration = Duration::from_secs(120);

fn weights() -> Weights {
    let cfg =
        ModelConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 64, mlp_mult: 2 };
    Weights::random(cfg, 31)
}

/// Silence the *expected* injected panics (typed payload) only.
fn install_quiet_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<fault::Injected>().is_none() {
                prev(info);
            }
        }));
    });
}

/// The fault plan is process-global: serialize scenarios within this file.
fn lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    install_quiet_hook();
    fault::ensure_env_armed();
    let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::disarm();
    guard
}

/// Drain a stream to (and including) its Final, then assert it is closed.
fn collect(rx: &mut StreamRx, i: usize) -> Vec<StreamEvent> {
    let mut events = Vec::new();
    loop {
        let ev = rx
            .recv_timeout(LONG)
            .unwrap_or_else(|e| panic!("request {i}: stream died before Final: {e:?}"));
        let terminal = matches!(ev, StreamEvent::Final(_));
        events.push(ev);
        if terminal {
            break;
        }
    }
    assert!(rx.try_recv().is_err(), "request {i}: event after Final");
    events
}

/// Assert the grammar over one collected stream and hand back its Final.
fn assert_grammar(i: usize, events: &[StreamEvent]) -> &Response {
    let id = events[0].id();
    assert!(
        matches!(events[0], StreamEvent::Queued { .. }),
        "request {i}: stream must open with Queued, got {:?}",
        events[0]
    );
    let mut prefilling_ts = None;
    let mut tokens: Vec<u16> = Vec::new();
    let mut token_ts: Vec<u64> = Vec::new();
    let mut resp = None;
    for (k, ev) in events.iter().enumerate() {
        assert_eq!(ev.id(), id, "request {i}: stream mixes request ids");
        match ev {
            StreamEvent::Queued { .. } => {
                assert_eq!(k, 0, "request {i}: duplicate Queued");
            }
            StreamEvent::Prefilling { ts_us, .. } => {
                // The only event that may sit between Queued and the tokens.
                assert_eq!(k, 1, "request {i}: Prefilling out of place");
                assert!(prefilling_ts.is_none(), "request {i}: duplicate Prefilling");
                prefilling_ts = Some(*ts_us);
            }
            StreamEvent::Token { index, token, ts_us, .. } => {
                assert!(prefilling_ts.is_some(), "request {i}: Token before Prefilling");
                assert!(resp.is_none(), "request {i}: Token after Final");
                assert_eq!(
                    *index as usize,
                    tokens.len(),
                    "request {i}: token indexes must be 0,1,2,…"
                );
                if let Some(&prev) = token_ts.last() {
                    assert!(*ts_us >= prev, "request {i}: token timestamps went backwards");
                }
                tokens.push(*token);
                token_ts.push(*ts_us);
            }
            StreamEvent::Final(r) => {
                assert_eq!(k, events.len() - 1, "request {i}: Final must be the last event");
                resp = Some(r);
            }
        }
    }
    let resp = resp.unwrap_or_else(|| panic!("request {i}: stream ended without Final"));
    assert_eq!(resp.id, id, "request {i}: Final carries the wrong id");
    assert_eq!(resp.tokens, tokens, "request {i}: Final tokens != streamed tokens");
    // Timing agreement, conditional on how far the request got: the Final's
    // derived breakdown is computed from the same stamps the events carried.
    if let Some(ts) = prefilling_ts {
        assert_eq!(resp.queue_us, ts, "request {i}: Prefilling ts != queue_us");
    }
    if let Some(&first) = token_ts.first() {
        assert_eq!(resp.ttft_us(), first, "request {i}: first Token ts != TTFT");
    }
    assert_eq!(
        resp.queue_us + resp.prefill_us + resp.decode_us,
        resp.total_us,
        "request {i}: timing phases must partition the total"
    );
    resp
}

#[test]
fn fault_free_streams_obey_the_grammar() {
    let _g = lock();
    let h = Engine::start(weights(), EngineOptions::default());
    let mut rxs = Vec::new();
    for i in 0..4usize {
        let prompt: Vec<u16> = (0..3 + i).map(|j| ((i * 5 + j) % 32) as u16).collect();
        rxs.push((i, 2 + i, h.submit(prompt, 2 + i, SubmitOptions::default()).unwrap()));
    }
    for (i, gen, mut rx) in rxs {
        let events = collect(&mut rx, i);
        let resp = assert_grammar(i, &events);
        assert_eq!(resp.finish, FinishReason::Done);
        assert_eq!(resp.tokens.len(), gen, "request {i}: fault-free run yields every token");
    }
    h.shutdown();
}

#[test]
fn randomized_fault_schedules_preserve_stream_grammar() {
    let _g = lock();
    let cases = if cfg!(miri) { 2 } else { 12 };
    let base_seed = fault::env_seed().unwrap_or(0x57E4);
    check(
        "stream grammar holds under chaos fault schedules",
        Config { cases, base_seed },
        |rng| {
            let mut clauses: Vec<String> = Vec::new();
            if rng.below(2) == 0 {
                clauses.push(format!("pool_alloc@{}", 1 + rng.below(12)));
            }
            if rng.below(2) == 0 {
                clauses.push(format!("panic_prefill@{}", 1 + rng.below(6)));
            }
            if rng.below(2) == 0 {
                clauses.push(format!("panic_decode@{}", 1 + rng.below(20)));
            }
            if !cfg!(miri) && rng.below(3) == 0 {
                let site = ["delay_prefill", "delay_decode", "delay_round"][rng.below(3) as usize];
                clauses.push(format!("{site}={}us", 100 * (1 + rng.below(8))));
            }
            fault::arm_str(&clauses.join(",")).unwrap();

            // Sometimes a tight hard-stop window, so drains cut requests off.
            let drain_timeout = if rng.below(3) == 0 {
                Duration::from_millis(rng.below(5))
            } else {
                Duration::from_secs(30)
            };
            let max_active = 1 + rng.below(4) as usize;
            let opts = EngineOptions {
                drain_timeout,
                policy: BatchPolicy { max_active, ..Default::default() },
                ..Default::default()
            };
            let h = Engine::start(weights(), opts);
            let n = if cfg!(miri) { 2 } else { 3 + rng.below(4) as usize };
            let mut rxs = Vec::with_capacity(n);
            for i in 0..n {
                let plen = 2 + rng.below(10) as usize;
                let prompt: Vec<u16> = (0..plen).map(|j| ((i * 7 + j * 3) % 32) as u16).collect();
                let gen = 1 + rng.below(6) as usize;
                let mut sopts = SubmitOptions::default();
                if rng.below(5) == 0 {
                    sopts = sopts.with_deadline(Duration::from_millis(rng.below(3)));
                }
                let rx = h.submit(prompt, gen, sopts).unwrap();
                if rng.below(4) == 0 {
                    rx.cancel();
                }
                rxs.push((i, gen, rx));
            }
            // Half the cases shut down while requests are still in flight —
            // the drain (or hard stop) must terminate every stream in
            // grammar, not just completed ones.
            let mut h = Some(h);
            if rng.below(2) == 0 {
                h.take().unwrap().shutdown();
            }
            for (i, gen, mut rx) in rxs {
                let events = collect(&mut rx, i);
                let resp = assert_grammar(i, &events);
                assert!(resp.tokens.len() <= gen, "request {i}: more tokens than asked for");
                if resp.finish == FinishReason::Done {
                    assert_eq!(resp.tokens.len(), gen, "request {i}: Done implies full output");
                }
            }
            if let Some(h) = h.take() {
                h.shutdown();
            }
        },
    );
}
