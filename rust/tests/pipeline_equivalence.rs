//! Cross-pipeline integration: all pipelines approximate the FP32 reference
//! on realistic workloads, with the fidelity ordering the paper reports.

use intattention::attention::{build_pipeline, AttentionConfig, PipelineKind};
use intattention::harness::workload::{clustered_qkv, random_qkv};
use intattention::util::prng::Pcg64;
use intattention::util::stats::{cosine_similarity, rmse};

fn reference(q: &intattention::tensor::MatF32, k: &intattention::tensor::MatF32, v: &intattention::tensor::MatF32) -> intattention::tensor::MatF32 {
    intattention::attention::fp32::reference_attention(q, k, v, intattention::softmax::index_softmax::Mask::None)
}

#[test]
fn all_pipelines_track_fp32_on_gaussian_workload() {
    let mut rng = Pcg64::seed_from_u64(1);
    let (l, d) = (128, 64);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let want = reference(&q, &k, &v);
    for (kind, min_cos) in [
        (PipelineKind::Fp32, 0.999999),
        (PipelineKind::Fp16, 0.9995),
        (PipelineKind::QuantOnly, 0.97), // INT8 ×127 P loses small probs (Table 9)
        (PipelineKind::IntAttention, 0.99),
        (PipelineKind::ExaqInt3, 0.97),
        (PipelineKind::ExaqInt2, 0.80),
    ] {
        let got = build_pipeline(kind, AttentionConfig::new(l, d)).forward(&q, &k, &v);
        let cos = cosine_similarity(want.as_slice(), got.as_slice());
        assert!(cos > min_cos, "{}: cos={cos} < {min_cos}", kind.name());
    }
}

#[test]
fn fidelity_ordering_on_clustered_workload() {
    // Paper Tables 5-7 ordering: IndexSoftmax > EXAQ-INT3 > EXAQ-INT2.
    let mut rng = Pcg64::seed_from_u64(2);
    let (l, d) = (128, 32);
    let mut err = std::collections::HashMap::new();
    for trial in 0..6 {
        let (q, k, v) = clustered_qkv(&mut rng, l, d, 6, 2.5);
        let want = reference(&q, &k, &v);
        for kind in [PipelineKind::IntAttention, PipelineKind::ExaqInt3, PipelineKind::ExaqInt2] {
            let got = build_pipeline(kind, AttentionConfig::new(l, d)).forward(&q, &k, &v);
            *err.entry(kind.name()).or_insert(0.0) += rmse(want.as_slice(), got.as_slice());
            let _ = trial;
        }
    }
    assert!(err["IntAttention"] < err["EXAQ(INT3)"], "{err:?}");
    assert!(err["EXAQ(INT3)"] < err["EXAQ(INT2)"], "{err:?}");
}

#[test]
fn causal_and_rectangular_shapes() {
    let mut rng = Pcg64::seed_from_u64(3);
    // causal square
    let (q, k, v) = random_qkv(&mut rng, 48, 16, 1.0);
    for kind in PipelineKind::headline() {
        let got = build_pipeline(kind, AttentionConfig::new(48, 16).causal()).forward(&q, &k, &v);
        assert_eq!((got.rows(), got.cols()), (48, 16), "{}", kind.name());
        assert!(got.as_slice().iter().all(|x| x.is_finite()));
    }
    // rectangular decode-style (1 query row)
    let q1 = intattention::tensor::MatF32::from_vec(1, 16, q.row(0).to_vec());
    for kind in PipelineKind::headline() {
        let got = build_pipeline(kind, AttentionConfig::new(48, 16)).forward(&q1, &k, &v);
        assert_eq!((got.rows(), got.cols()), (1, 16), "{}", kind.name());
    }
}

#[test]
fn intattention_faster_than_quant_only_at_scale() {
    // The paper's headline ratio (Table 8) at a modest size: IntAttention
    // must beat Quant-Only once L is nontrivial.
    let mut rng = Pcg64::seed_from_u64(4);
    let (l, d) = (1024, 128);
    let (q, k, v) = random_qkv(&mut rng, l, d, 1.0);
    let time = |kind| {
        let mut p = build_pipeline(kind, AttentionConfig::new(l, d));
        let _ = p.forward(&q, &k, &v); // warm
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            let _ = p.forward(&q, &k, &v);
        }
        t0.elapsed().as_secs_f64()
    };
    let t_qo = time(PipelineKind::QuantOnly);
    let t_ia = time(PipelineKind::IntAttention);
    let t_fp32 = time(PipelineKind::Fp32);
    assert!(t_ia < t_qo * 1.05, "IntAttention {t_ia:.3}s !< QuantOnly {t_qo:.3}s");
    assert!(t_ia < t_fp32 * 0.6, "IntAttention {t_ia:.3}s !≪ FP32 {t_fp32:.3}s");
}

#[test]
fn stage_instrumentation_consistent_with_kind() {
    let mut rng = Pcg64::seed_from_u64(5);
    let (q, k, v) = random_qkv(&mut rng, 96, 32, 1.0);
    use intattention::util::timer::Stage;
    // Quant-Only has the detour; IntAttention does not.
    let mut qo = build_pipeline(PipelineKind::QuantOnly, AttentionConfig::new(96, 32));
    let _ = qo.forward(&q, &k, &v);
    assert!(qo.stage_times().get_ns(Stage::Dequantize) > 0);
    assert!(qo.stage_times().get_ns(Stage::Requantize) > 0);
    let mut ia = build_pipeline(PipelineKind::IntAttention, AttentionConfig::new(96, 32));
    let _ = ia.forward(&q, &k, &v);
    assert_eq!(ia.stage_times().get_ns(Stage::Dequantize), 0);
    assert_eq!(ia.stage_times().get_ns(Stage::Requantize), 0);
    assert_eq!(ia.op_counts().fp32_exp, 0);
    assert!(qo.op_counts().fp32_exp > 0);
}
