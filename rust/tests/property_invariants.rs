//! Randomized property tests over the core invariants, driven by the
//! in-crate miniature proptest harness (seeds reported on failure).

use intattention::attention::{build_pipeline, AttentionConfig, PipelineKind};
use intattention::quant::{dequantize_i8, quantize_i8, quantize_p_u8};
use intattention::softmax::index_softmax::{IndexSoftmax, Mask, MulShiftDiv};
use intattention::tensor::{MatF32, MatI32};
use intattention::util::proptest::{check, Config};

fn rand_mat(rng: &mut intattention::util::prng::Pcg64, r: usize, c: usize, s: f32) -> MatF32 {
    MatF32::from_vec(r, c, (0..r * c).map(|_| rng.normal_ms(0.0, s)).collect())
}

#[test]
fn prop_quantization_roundtrip_error_bounded() {
    check("quant roundtrip ≤ scale/2", Config::cases(60), |rng| {
        let r = 1 + rng.below(16) as usize;
        let c = 1 + rng.below(64) as usize;
        let s = rng.uniform(0.01, 50.0);
        let x = rand_mat(rng, r, c, s);
        let q = quantize_i8(&x);
        let back = dequantize_i8(&q);
        let bound = q.scale / 2.0 + 1e-6;
        for (&a, &b) in x.as_slice().iter().zip(back.as_slice()) {
            assert!((a - b).abs() <= bound, "|{a}-{b}| > {bound}");
        }
    });
}

#[test]
fn prop_index_softmax_rows_normalize_and_order() {
    check("IndexSoftmax normalization + order", Config::cases(50), |rng| {
        let rows = 1 + rng.below(6) as usize;
        let cols = 2 + rng.below(96) as usize;
        let spread = 1 + rng.below(40_000) as i64;
        let alpha = rng.uniform(1e-4, 0.1);
        let logits = MatI32::from_vec(
            rows,
            cols,
            (0..rows * cols)
                .map(|_| rng.range_i64(-spread, spread + 1) as i32)
                .collect(),
        );
        let isx = IndexSoftmax::default();
        let p = isx.forward(&logits, alpha, Mask::None);
        for r in 0..rows {
            // (1) rows sum to ≈255 (integer normalization, eq. 15);
            // worst case each of `cols` entries rounds by ±0.5.
            let tol = 16.max(cols as i32 / 3);
            let s: i32 = p.row(r).iter().map(|&x| x as i32).sum();
            assert!((s - 255).abs() <= tol, "row {r} sum {s} (cols {cols})");
            // (2) monotone: larger logit ⇒ probability not smaller
            let row_l = logits.row(r);
            let row_p = p.row(r);
            for i in 0..cols {
                for j in 0..cols {
                    if row_l[i] > row_l[j] {
                        assert!(
                            row_p[i] >= row_p[j],
                            "order violated at logits {} vs {}",
                            row_l[i],
                            row_l[j]
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn prop_index_softmax_invariant_to_logit_shift() {
    // Softmax(A + c) == Softmax(A): max-subtraction makes the integer
    // surrogate shift-invariant too.
    check("shift invariance", Config::cases(40), |rng| {
        let cols = 2 + rng.below(64) as usize;
        let shift = rng.range_i64(-100_000, 100_000) as i32;
        let base: Vec<i32> = (0..cols).map(|_| rng.range_i64(-20_000, 20_000) as i32).collect();
        let shifted: Vec<i32> = base.iter().map(|&x| x.saturating_add(shift)).collect();
        let isx = IndexSoftmax::default();
        let alpha = rng.uniform(1e-4, 0.05);
        let p1 = isx.forward(&MatI32::from_vec(1, cols, base), alpha, Mask::None);
        let p2 = isx.forward(&MatI32::from_vec(1, cols, shifted), alpha, Mask::None);
        assert_eq!(p1, p2);
    });
}

#[test]
fn prop_mulshift_div_exact() {
    check("mul-shift division exactness", Config::cases(80), |rng| {
        let d = 1 + rng.below(1 << 24);
        let ms = MulShiftDiv::new(d);
        for _ in 0..32 {
            let x = rng.below((1 << 31) - (1 << 25));
            assert_eq!(ms.div_floor(x), x / d);
            assert_eq!(ms.div_round(x), (x + d / 2) / d);
        }
    });
}

#[test]
fn prop_p_u8_quantization_never_exceeds_range() {
    check("P̂ stays a probability", Config::cases(40), |rng| {
        let cols = 1 + rng.below(128) as usize;
        let raw: Vec<f32> = (0..cols).map(|_| rng.next_f32()).collect();
        let z: f32 = raw.iter().sum::<f32>().max(1e-6);
        let p = MatF32::from_vec(1, cols, raw.iter().map(|&x| x / z).collect());
        let q = quantize_p_u8(&p);
        // round(255·p) for p ∈ [0,1] stays in u8 and preserves argmax.
        let argmax_f = p.row(0).iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
        let max_q = *q.row(0).iter().max().unwrap();
        assert_eq!(q.row(0)[argmax_f], max_q);
    });
}

#[test]
fn prop_pipelines_finite_on_adversarial_inputs() {
    // Degenerate inputs (all-zero, constant, huge magnitude, tiny magnitude)
    // must never produce NaN/Inf in any pipeline — the Table 10 claim.
    check("no NaN/Inf on degenerate inputs", Config::cases(24), |rng| {
        let (l, d) = (16 + rng.below(32) as usize, 8);
        let kind = match rng.below(4) {
            0 => PipelineKind::Fp32,
            1 => PipelineKind::Fp16,
            2 => PipelineKind::QuantOnly,
            _ => PipelineKind::IntAttention,
        };
        let mode = rng.below(4);
        let gen = |rng: &mut intattention::util::prng::Pcg64| match mode {
            0 => MatF32::zeros(l, d),
            1 => MatF32::from_vec(l, d, vec![3.7; l * d]),
            2 => rand_mat(rng, l, d, 1e4),
            _ => rand_mat(rng, l, d, 1e-6),
        };
        let (q, k, v) = (gen(rng), gen(rng), gen(rng));
        let out = build_pipeline(kind, AttentionConfig::new(l, d)).forward(&q, &k, &v);
        assert!(
            out.as_slice().iter().all(|x| x.is_finite()),
            "{} produced non-finite output on mode {mode}",
            kind.name()
        );
    });
}

#[test]
fn prop_grouped_quant_never_worse_than_per_tensor_on_outliers() {
    use intattention::quant::{dequantize_grouped_i8, quantize_grouped_i8, GroupScheme};
    check("per-row ≥ per-tensor under row outliers", Config::cases(30), |rng| {
        let (r, c) = (4 + rng.below(8) as usize, 16);
        let mut x = rand_mat(rng, r, c, 0.3);
        let hot = rng.below(r as u64) as usize;
        let boost = rng.uniform(50.0, 2000.0);
        for v in x.row_mut(hot) {
            *v *= boost;
        }
        let pt = dequantize_grouped_i8(&quantize_grouped_i8(&x, GroupScheme::PerTensor));
        let pr = dequantize_grouped_i8(&quantize_grouped_i8(&x, GroupScheme::PerRow));
        let err = |m: &MatF32| -> f64 {
            let mut e = 0.0;
            for rr in 0..r {
                if rr != hot {
                    e += intattention::util::stats::rmse(x.row(rr), m.row(rr));
                }
            }
            e
        };
        assert!(err(&pr) <= err(&pt) + 1e-9, "{} > {}", err(&pr), err(&pt));
    });
}
