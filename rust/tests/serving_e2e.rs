//! Serving-engine end-to-end: trace replay, batching overlap, backpressure,
//! prefix sharing and per-pipeline throughput sanity under the coordinator.

use intattention::attention::{kv_page_rows, page_pool_stats, PipelineKind};
use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::prefix::PrefixIndex;
use intattention::coordinator::{
    Engine, EngineOptions, FinishReason, StreamEvent, SubmitError, SubmitOptions,
};
use intattention::model::config::ModelConfig;
use intattention::model::lm::KvCache;
use intattention::model::weights::Weights;

fn weights() -> Weights {
    let cfg = ModelConfig { vocab: 64, d_model: 16, n_layers: 1, n_heads: 2, max_seq: 96, mlp_mult: 2 };
    Weights::random(cfg, 42)
}

#[test]
fn trace_replay_completes_all_requests() {
    for kind in [PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let opts = EngineOptions { attention: kind, ..Default::default() };
        let h = Engine::start(weights(), opts);
        let rxs: Vec<_> = (0..10)
            .map(|i| {
                let plen = 4 + (i % 5) * 8;
                let prompt: Vec<u16> = (0..plen).map(|j| (j * 13 % 64) as u16).collect();
                h.submit(prompt, 4, SubmitOptions::sampling(0.5, 8)).unwrap()
            })
            .collect();
        for rx in rxs {
            let resp = rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap();
            assert_eq!(resp.tokens.len(), 4);
            assert!(resp.total_us >= resp.prefill_us);
        }
        let snap = h.shutdown();
        assert_eq!(snap.completed, 10, "{}", kind.name());
        assert_eq!(snap.rejected, 0);
        assert!(snap.throughput_tok_s > 0.0);
    }
}

#[test]
fn continuous_batching_overlaps_decodes() {
    let opts = EngineOptions {
        policy: BatchPolicy { max_active: 4, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(weights(), opts);
    let rxs: Vec<_> = (0..8)
        .map(|_| h.submit(vec![1, 2, 3, 4], 12, SubmitOptions::default()).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap();
    }
    let snap = h.shutdown();
    assert!(snap.peak_active >= 2, "peak_active={}", snap.peak_active);
    assert!(snap.peak_active <= 4, "policy bound violated: {}", snap.peak_active);
}

#[test]
fn queue_bound_produces_backpressure_not_deadlock() {
    let opts = EngineOptions { max_queue: 1, ..Default::default() };
    let h = Engine::start(weights(), opts);
    let mut ok = Vec::new();
    let mut full = 0;
    for _ in 0..30 {
        match h.submit(vec![1; 32], 8, SubmitOptions::default()) {
            Ok(rx) => ok.push(rx),
            Err(SubmitError::QueueFull) => full += 1,
            Err(e) => panic!("{e}"),
        }
    }
    assert!(full > 0, "expected rejections with queue depth 1");
    for rx in ok {
        rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap();
    }
    let snap = h.shutdown();
    assert_eq!(snap.rejected as usize, full);
}

#[test]
fn kv_budget_head_of_line_big_request_not_starved() {
    // Budget pressure stress: a big request arrives early among a stream of
    // small ones. Shortest-first admission would sort the smalls ahead of it
    // every round; the engine's kv_head pinning must keep them from
    // leapfrogging the deferred big request forever. Everything completes.
    //
    // The page budget fits exactly one small request's projection (4 prompt
    // + 4 gen = 8 tokens), so requests serialize; the big request (40 + 8 =
    // 48 tokens) projects at least as many pages and runs only when the
    // active set drains. (Computed from the live page size so the test
    // holds under the CI `INTATTN_KV_PAGE=2` run too.)
    let w = weights();
    let small_pages = KvCache::pages_for_tokens(8, &w.cfg);
    let big_pages = KvCache::pages_for_tokens(48, &w.cfg);
    let opts = EngineOptions {
        attention: PipelineKind::IntAttention,
        policy: BatchPolicy { max_kv_pages: small_pages, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(w, opts);
    let mut rxs = Vec::new();
    for i in 0..2 {
        rxs.push(h.submit(vec![1, 2, (i + 1) as u16, 4], 4, SubmitOptions::default()).unwrap());
    }
    rxs.push(h.submit(vec![7; 40], 8, SubmitOptions::default()).unwrap()); // the big one
    // Keep the queue deeper than max_active (8) with shorter prompts, so
    // shortest-first on its own would never re-select the big request —
    // regression for the kv_head livelock (selected-then-vetoed rounds
    // admitting nothing, forever).
    for i in 0..12 {
        rxs.push(h.submit(vec![1, 2, (i + 10) as u16, 4], 4, SubmitOptions::default()).unwrap());
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_all_timeout(std::time::Duration::from_secs(120))
            .unwrap_or_else(|e| panic!("request {i} starved: {e:?}"));
        assert!(!resp.tokens.is_empty());
    }
    let snap = h.shutdown();
    assert_eq!(snap.completed, 15);
    // Page accounting is exact allocated capacity, so the observed peak can
    // never exceed the largest single admission (the over-budget big
    // request runs alone) or the budget itself.
    assert!(
        snap.peak_kv_pages <= big_pages.max(small_pages),
        "kv page budget overshoot: {} pages (budget {small_pages}, big {big_pages})",
        snap.peak_kv_pages
    );
}

#[test]
fn page_recycling_lets_queued_request_admit_after_another_finishes() {
    // A page budget sized for exactly one request forces the queue to wait
    // on retirement: each finishing request frees its pages back to the
    // process-wide pool that round, the freed budget admits the next
    // request, and the pool hands the recycled pages straight back out.
    let w = weights();
    let one_seq = KvCache::pages_for_tokens(8, &w.cfg); // 4 prompt + 4 gen
    let recycled_before = page_pool_stats().recycled;
    let opts = EngineOptions {
        attention: PipelineKind::IntAttention,
        policy: BatchPolicy { max_kv_pages: one_seq, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(w, opts);
    let rxs: Vec<_> = (0..3)
        .map(|i| h.submit(vec![1, 2, 3, (4 + i) as u16], 4, SubmitOptions::default()).unwrap())
        .collect();
    for rx in rxs {
        let resp = rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(resp.tokens.len(), 4);
    }
    let snap = h.shutdown();
    assert_eq!(snap.completed, 3);
    assert!(
        snap.peak_kv_pages <= one_seq,
        "budget of one sequence held: peak {} > {one_seq}",
        snap.peak_kv_pages
    );
    // Requests 2 and 3 could only admit after a predecessor finished; their
    // identical page geometry means the pool's free list served them, so
    // the process-wide recycle counter must have advanced.
    let recycled_after = page_pool_stats().recycled;
    assert!(
        recycled_after > recycled_before,
        "retired pages must be recycled, not re-allocated \
         ({recycled_before} → {recycled_after})"
    );
}

#[test]
fn batched_decode_rounds_preserve_greedy_outputs() {
    // The engine's step (3b) decodes its whole active set through one
    // decode_step_batch call. Greedy outputs must therefore not depend on
    // how many sequences share a round: a max_active=1 engine (batch width
    // 1) and a max_active=6 engine (all six sequences in one grouped call)
    // must produce identical tokens per request.
    let w = weights();
    let prompts: Vec<Vec<u16>> = (0..6u16)
        .map(|i| (0..4 + i).map(|j| (j * 7 + i) % 64).collect())
        .collect();
    let run = |max_active: usize| -> Vec<Vec<u16>> {
        let opts = EngineOptions {
            attention: PipelineKind::IntAttention,
            policy: BatchPolicy { max_active, ..Default::default() },
            ..Default::default()
        };
        let h = Engine::start(w.clone(), opts);
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| h.submit(p.clone(), 6, SubmitOptions::default()).unwrap())
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap().tokens)
            .collect();
        h.shutdown();
        out
    };
    assert_eq!(run(1), run(6), "greedy decode must not depend on batch width");
}

/// The engine's prefix-sharing granularity for a given prefill chunk, read
/// from the real policy (`PrefixIndex`) so these tests track any future
/// change to the alignment rule instead of re-deriving it.
fn share_align(chunk: usize) -> usize {
    PrefixIndex::new(kv_page_rows(), chunk, 1)
        .expect("chunked prefill → sharing is possible")
        .align()
}

#[test]
fn prefix_sharing_is_invisible_and_charges_prefix_pages_once() {
    // Two sequential requests with the same prompt: the second must adopt
    // the registered prefix (prefix_hits == 1, shared_kv_pages == exactly
    // the prefix's page set — the refcount-counter evidence that it
    // allocated only its suffix), and greedy outputs must be byte-identical
    // to a sharing-disabled engine — sharing is invisible.
    let w = weights();
    let chunk = 8usize;
    let prompt: Vec<u16> = (0..80).map(|i| (i * 13 % 64) as u16).collect();
    let align = share_align(chunk);
    // Longest adoptable prefix: aligned, and short of the last token.
    let adopt_len = (prompt.len() - 1) / align * align;
    assert!(
        adopt_len > 0,
        "test geometry must allow sharing (align {align} vs prompt {})",
        prompt.len()
    );
    for kind in [PipelineKind::IntAttention, PipelineKind::ExaqInt2] {
        let run = |share: bool| {
            let opts = EngineOptions {
                attention: kind,
                policy: BatchPolicy {
                    prefill_chunk: chunk,
                    prefix_share: share,
                    ..Default::default()
                },
                ..Default::default()
            };
            let h = Engine::start(w.clone(), opts);
            let mut outs = Vec::new();
            for _ in 0..2 {
                // Sequential: the second submit only enters after the first
                // completed, so its adoption length is deterministic.
                let rx = h.submit(prompt.clone(), 4, SubmitOptions::default()).unwrap();
                outs.push(rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap().tokens);
            }
            (outs, h.shutdown())
        };
        let (shared_outs, shared_snap) = run(true);
        let (unshared_outs, unshared_snap) = run(false);
        assert_eq!(
            shared_outs, unshared_outs,
            "{}: sharing must be byte-invisible to greedy serving",
            kind.name()
        );
        assert_eq!(shared_snap.prefix_hits, 1, "{}", kind.name());
        assert_eq!(
            shared_snap.shared_kv_pages,
            KvCache::pages_for_tokens(adopt_len, &w.cfg) as u64,
            "{}: the adopter must take exactly the prefix page set by reference",
            kind.name()
        );
        assert_eq!(shared_snap.shared_prefix_tokens, adopt_len as u64, "{}", kind.name());
        assert_eq!(unshared_snap.prefix_hits, 0, "{}", kind.name());
        // The adopter skipped recomputing the prefix: strictly fewer prefill
        // tokens were processed than in the unshared run.
        assert_eq!(
            shared_snap.prefill_tokens + adopt_len as u64,
            unshared_snap.prefill_tokens,
            "{}: adopted tokens must not be re-prefilled",
            kind.name()
        );
    }
}

#[test]
fn concurrent_same_prompt_requests_converge_on_shared_prefix() {
    // N identical prompts submitted together: trailing requests upgrade to
    // the leader's registered prefixes mid-prefill, so the fleet converges
    // onto one set of prefix pages. Outputs stay identical per request
    // (greedy + byte-invisible sharing).
    let w = weights();
    let chunk = 8usize;
    let prompt: Vec<u16> = (0..72).map(|i| (i * 7 % 64) as u16).collect();
    let adopt_possible = (prompt.len() - 1) / share_align(chunk) * share_align(chunk) > 0;
    let opts = EngineOptions {
        attention: PipelineKind::IntAttention,
        policy: BatchPolicy { prefill_chunk: chunk, prefix_share: true, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(w, opts);
    let rxs: Vec<_> = (0..4).map(|_| h.submit(prompt.clone(), 5, SubmitOptions::default()).unwrap()).collect();
    let outs: Vec<Vec<u16>> = rxs
        .into_iter()
        .map(|rx| rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap().tokens)
        .collect();
    let snap = h.shutdown();
    assert_eq!(snap.completed, 4);
    for o in &outs[1..] {
        assert_eq!(o, &outs[0], "identical prompts must produce identical greedy outputs");
    }
    if adopt_possible {
        assert!(
            snap.prefix_hits >= 3,
            "trailing same-prompt requests must adopt ({} hits)",
            snap.prefix_hits
        );
        assert!(snap.shared_kv_pages > 0);
    }
}

#[test]
fn oversized_and_empty_prompts_rejected_cleanly() {
    let h = Engine::start(weights(), EngineOptions::default());
    assert!(matches!(h.submit(vec![], 1, SubmitOptions::default()), Err(SubmitError::BadRequest)));
    assert!(matches!(
        h.submit(vec![1; 200], 1, SubmitOptions::default()),
        Err(SubmitError::BadRequest)
    ));
    // Engine still serves after rejections.
    let rx = h.submit(vec![1, 2], 2, SubmitOptions::default()).unwrap();
    rx.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
    h.shutdown();
}

#[test]
fn dropped_receiver_cancels_and_frees_pages_for_the_next_request() {
    // A client that hangs up mid-generation (drops its StreamRx) must not
    // keep burning rounds and KV pages: the engine treats the hang-up as an
    // implicit cancel, retires the request at a round boundary, and the
    // freed pages admit the next request.
    //
    // Determinism: the victim's prefill is made slow (512-token prompt,
    // chunk 4, d_model 128 × 2 layers ⇒ ~128 multi-ms rounds), and the drop
    // happens only after the live `prefill_tokens` counter proves the
    // victim is mid-prefill — no sleep-and-hope timing.
    let cfg = ModelConfig { vocab: 64, d_model: 128, n_layers: 2, n_heads: 4, max_seq: 600, mlp_mult: 2 };
    let w = Weights::random(cfg, 7);
    let victim_prompt: Vec<u16> = (0..512).map(|i| (i * 13 % 64) as u16).collect();
    // Page budget = exactly the victim's projection: while the victim is
    // resident nothing else can admit, so the follower finishing at all is
    // proof the drop returned the victim's pages that round.
    let budget = KvCache::pages_for_tokens(victim_prompt.len() + 8, &w.cfg);
    let opts = EngineOptions {
        attention: PipelineKind::IntAttention,
        policy: BatchPolicy { prefill_chunk: 4, max_kv_pages: budget, ..Default::default() },
        ..Default::default()
    };
    let h = Engine::start(w, opts);
    let victim = h.submit(victim_prompt, 8, SubmitOptions::default()).unwrap();
    let started = std::time::Instant::now();
    while h.metrics().prefill_tokens < 8 {
        assert!(
            started.elapsed() < std::time::Duration::from_secs(120),
            "victim never started prefilling"
        );
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    drop(victim); // client hangs up mid-prefill
    let follower = h.submit(vec![1, 2, 3, 4], 4, SubmitOptions::default()).unwrap();
    let resp = follower.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap();
    assert_eq!(resp.finish, FinishReason::Done, "follower must be served after the hang-up");
    assert_eq!(resp.tokens.len(), 4);
    let snap = h.shutdown();
    assert_eq!(snap.finished_cancelled, 1, "hang-up retired as Cancelled");
    assert_eq!(snap.finished_done, 1);
    assert_eq!(snap.completed, 2);
    assert!(
        snap.peak_kv_pages <= budget,
        "victim and follower never resident together: peak {} > budget {budget}",
        snap.peak_kv_pages
    );
    assert!(
        snap.prefill_tokens < 512 + 4,
        "cancelled prefill must stop early ({} tokens prefilled)",
        snap.prefill_tokens
    );
}

#[test]
fn streamed_tokens_are_byte_identical_to_the_final_response() {
    // Streaming is pure delivery, not a numerics change: per pipeline, the
    // Token-event sequence a client consumes incrementally must equal the
    // terminal `Final.tokens` byte-for-byte, and must equal the greedy
    // output of a second engine whose client only reads the terminal via
    // the `recv_all` shim.
    let w = weights();
    let prompts: Vec<Vec<u16>> = (0..4u16)
        .map(|i| (0..6 + i).map(|j| (j * 11 + i) % 64).collect())
        .collect();
    for kind in [PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let opts = || EngineOptions { attention: kind, ..Default::default() };
        // Engine A: drain event-by-event.
        let h = Engine::start(w.clone(), opts());
        let mut streamed_outs = Vec::new();
        for p in &prompts {
            let mut rx = h.submit(p.clone(), 6, SubmitOptions::default()).unwrap();
            let mut tokens = Vec::new();
            let resp = loop {
                match rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap() {
                    StreamEvent::Token { token, .. } => tokens.push(token),
                    StreamEvent::Final(r) => break r,
                    _ => {}
                }
            };
            assert_eq!(tokens, resp.tokens, "{}: stream vs Final drifted", kind.name());
            streamed_outs.push(tokens);
        }
        h.shutdown();
        // Engine B: terminal-only clients via the shim.
        let h = Engine::start(w.clone(), opts());
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| h.submit(p.clone(), 6, SubmitOptions::default()).unwrap())
            .collect();
        let shim_outs: Vec<Vec<u16>> = rxs
            .into_iter()
            .map(|rx| rx.recv_all_timeout(std::time::Duration::from_secs(120)).unwrap().tokens)
            .collect();
        h.shutdown();
        assert_eq!(streamed_outs, shim_outs, "{}: delivery mode changed outputs", kind.name());
    }
}

#[test]
fn ttft_reported_smaller_for_short_prompts() {
    let h = Engine::start(weights(), EngineOptions::default());
    let short = h.submit(vec![1, 2], 2, SubmitOptions::default()).unwrap();
    let r_short = short.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
    let long = h.submit(vec![1; 80], 2, SubmitOptions::default()).unwrap();
    let r_long = long.recv_all_timeout(std::time::Duration::from_secs(60)).unwrap();
    assert!(
        r_long.prefill_us > r_short.prefill_us,
        "80-token prefill {}us !> 2-token {}us",
        r_long.prefill_us,
        r_short.prefill_us
    );
    h.shutdown();
}
