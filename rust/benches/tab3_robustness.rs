//! Regenerates paper Table 3/7 (substituted): long-context robustness —
//! perplexity at growing context lengths per pipeline.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let w = exp::load_or_random_weights();
    let mut out = String::new();
    for (ctx, rows) in exp::tab3_long_context(&w, &[64, 128, 256], 4) {
        let t = exp::render_lm_fidelity(&rows, &format!("Table 3 — long-context fidelity @ ctx={ctx}"));
        t.print();
        out.push_str(&t.render());
    }
    let _ = write_report("tab3_robustness", &out, None);
}
