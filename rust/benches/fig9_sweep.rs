//! Regenerates paper Figure 9: (b, c) hyperparameter sensitivity of
//! IndexSoftmax — the plateau for b ≥ 4, c ∈ [5.5, 7.7].
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let cells = exp::fig9_sweep(&[2, 3, 4, 5, 6, 8], &[4.4, 5.5, 6.6, 7.7, 8.8], 192, 64);
    let table = exp::render_fig9(&cells);
    table.print();
    let _ = write_report("fig9_sweep", &table.render(), None);
}
