//! Regenerates paper Table 8: end-to-end attention latency (ms) per
//! pipeline × sequence length on both platform configs.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;
use intattention::util::threadpool::default_threads;

fn main() {
    let lens = exp::default_seq_lens();
    let a = exp::speed_sweep(&lens, exp::HEAD_DIM, 1);
    let b = exp::speed_sweep(&lens, exp::HEAD_DIM, default_threads());
    let table = exp::render_tab8(&a, &b);
    table.print();
    let _ = write_report("tab8_latency", &table.render(), None);
}
