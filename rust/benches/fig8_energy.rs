//! Regenerates paper Figure 8: modeled energy per attention iteration,
//! normalized to FP16 (analytic op-count model; DESIGN.md §2 substitution).
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let lens = exp::default_seq_lens();
    let rows = exp::fig8_energy(&lens, exp::HEAD_DIM);
    let table = exp::render_fig8(&rows);
    table.print();
    let _ = write_report("fig8_energy", &table.render(), None);
}
