//! Regenerates paper Figure 5: LUT fidelity under a 32-byte budget
//! (IndexSoftmax 32×u8 vs EXAQ INT3/INT2).
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let rows = exp::fig5_lut_resolution();
    let table = exp::render_fig5(&rows);
    table.print();
    let _ = write_report("fig5_lut_resolution", &table.render(), None);
}
