//! Regenerates paper Table 9: INT8(×127) vs UINT8(×255) quantization of the
//! probability matrix P — CosSim / relative-L1 / RMSE vs FP32.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let (i8f, u8f) = exp::tab9_p_quant(512, 64, 6);
    let table = exp::render_tab9(&i8f, &u8f);
    table.print();
    let _ = write_report("tab9_p_quant", &table.render(), None);
}
