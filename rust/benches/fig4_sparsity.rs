//! Regenerates paper Figure 4: softmax mass concentration in top logits.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let rows = exp::fig4_sparsity(512, 64);
    let table = exp::render_fig4(&rows);
    table.print();
    let _ = write_report("fig4_sparsity", &table.render(), None);
}
