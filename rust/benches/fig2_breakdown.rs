//! Regenerates paper Figure 2: share of the dequantize→softmax→requantize
//! path per precision across sequence lengths.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let lens = exp::default_seq_lens();
    let rows = exp::fig2_breakdown(&lens, exp::HEAD_DIM, 1);
    let table = exp::render_fig2(&rows);
    table.print();
    let _ = write_report("fig2_breakdown", &table.render(), None);
}
