//! Regenerates paper Tables 4/5/6 (substituted): softmax-only ablation —
//! IndexSoftmax vs EXAQ INT2/INT3 inside the same integer pipeline.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let w = exp::load_or_random_weights();
    let rows = exp::tab5_softmax_ablation(&w, 6, 160);
    let table = exp::render_lm_fidelity(&rows, "Table 5 — softmax-only ablation");
    table.print();
    let _ = write_report("tab5_softmax_ablation", &table.render(), None);
}
