//! Serving load generator — the standing "heavy traffic" benchmark over
//! the streaming engine. Open-loop Poisson arrivals (submission times come
//! from the trace, never from request completion — queueing delay is part
//! of the measurement, as in real serving load tests) with Zipf-mixed
//! prompt lengths, one collector thread per request consuming its event
//! stream the way a network client would. Reports, per pipeline:
//!
//! * **TTFT p50/p95/p99** — client-observed submit → first Token event;
//! * **inter-token latency p50/p95/p99** — client-observed gaps between
//!   consecutive Token events of one request;
//! * **aggregate tok/s** — streamed tokens over the wall clock;
//! * rejected submits (backpressure at the configured queue bound).
//!
//! Written as the `serving_load` report (rows keyed
//! `<pipeline>/<metric>`), compared across commits by `benchdiff`.

use intattention::attention::PipelineKind;
use intattention::coordinator::batcher::BatchPolicy;
use intattention::coordinator::{Engine, EngineOptions, StreamEvent, SubmitOptions};
use intattention::harness::experiments::load_or_random_weights;
use intattention::harness::report::{kv_rows_json, write_report};
use intattention::harness::workload::request_trace;
use intattention::util::prng::Pcg64;
use intattention::util::stats::percentile;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// What one simulated client saw of its own stream.
struct ClientObs {
    ttft_ms: Option<f64>,
    gaps_ms: Vec<f64>,
    tokens: usize,
    ok: bool,
}

fn pct(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        percentile(xs, p)
    }
}

fn main() {
    let fast = intattention::util::env::knobs().bench_fast;
    // Fast mode keeps the report shape identical on a fraction of the load.
    let (n_requests, rate_per_s, max_gen) = if fast { (16, 32.0, 6) } else { (96, 24.0, 16) };
    let weights = load_or_random_weights();
    let max_seq = weights.cfg.max_seq;

    let mut lines = vec![
        "serving_load — open-loop Poisson arrivals against the streaming engine".to_string(),
        format!("requests {n_requests} | rate {rate_per_s}/s | max gen {max_gen}"),
        String::new(),
    ];
    let mut rows: Vec<(String, f64)> = Vec::new();

    for kind in [PipelineKind::QuantOnly, PipelineKind::IntAttention] {
        let opts = EngineOptions {
            attention: kind,
            policy: BatchPolicy { max_active: 6, ..Default::default() },
            max_queue: 64,
            ..Default::default()
        };
        let h = Engine::start(weights.clone(), opts);
        let mut rng = Pcg64::seed_from_u64(0x10AD);
        let trace = request_trace(&mut rng, n_requests, rate_per_s, &[8, 24, 48], max_gen);
        let (obs_tx, obs_rx) = mpsc::channel::<ClientObs>();
        let mut collectors = Vec::new();
        let mut rejected = 0usize;
        let t0 = Instant::now();
        for r in &trace {
            // Open loop: pace by the trace's arrival stamp, regardless of
            // how far behind the engine is.
            if let Some(sleep) = Duration::from_micros(r.arrival_us).checked_sub(t0.elapsed()) {
                std::thread::sleep(sleep);
            }
            let plen = r.prompt_len.min(max_seq.saturating_sub(r.gen_len + 1)).max(1);
            let prompt: Vec<u16> = (0..plen).map(|i| (i * 31 % 64) as u16).collect();
            match h.submit(prompt, r.gen_len, SubmitOptions::default()) {
                Ok(mut rx) => {
                    let tx = obs_tx.clone();
                    let submitted = Instant::now();
                    collectors.push(std::thread::spawn(move || {
                        let mut obs = ClientObs {
                            ttft_ms: None,
                            gaps_ms: Vec::new(),
                            tokens: 0,
                            ok: false,
                        };
                        let mut last: Option<Instant> = None;
                        loop {
                            match rx.recv() {
                                Ok(StreamEvent::Token { .. }) => {
                                    let now = Instant::now();
                                    if obs.ttft_ms.is_none() {
                                        obs.ttft_ms = Some((now - submitted).as_secs_f64() * 1e3);
                                    }
                                    if let Some(prev) = last {
                                        obs.gaps_ms.push((now - prev).as_secs_f64() * 1e3);
                                    }
                                    last = Some(now);
                                    obs.tokens += 1;
                                }
                                Ok(StreamEvent::Final(resp)) => {
                                    obs.ok = resp.finish.is_ok();
                                    break;
                                }
                                Ok(_) => {}
                                Err(_) => break,
                            }
                        }
                        let _ = tx.send(obs);
                    }));
                }
                Err(_) => rejected += 1,
            }
        }
        drop(obs_tx);
        for c in collectors {
            let _ = c.join();
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let snap = h.shutdown();

        let all: Vec<ClientObs> = obs_rx.try_iter().collect();
        let ttfts: Vec<f64> = all.iter().filter(|o| o.ok).filter_map(|o| o.ttft_ms).collect();
        let gaps: Vec<f64> = all.iter().flat_map(|o| o.gaps_ms.iter().copied()).collect();
        let streamed: usize = all.iter().map(|o| o.tokens).sum();
        let tok_s = streamed as f64 / wall_s;

        let label = match kind {
            PipelineKind::QuantOnly => "quant_only",
            _ => "int_attention",
        };
        lines.push(format!(
            "{:<14} ttft p50 {:>7.2} ms  p95 {:>7.2} ms  p99 {:>7.2} ms | \
             itl p50 {:>6.2} ms  p95 {:>6.2} ms  p99 {:>6.2} ms | \
             {:>8.1} tok/s streamed | {} rejected",
            kind.name(),
            pct(&ttfts, 50.0),
            pct(&ttfts, 95.0),
            pct(&ttfts, 99.0),
            pct(&gaps, 50.0),
            pct(&gaps, 95.0),
            pct(&gaps, 99.0),
            tok_s,
            rejected,
        ));
        lines.push(format!("  engine: {}", snap.render()));
        rows.push((format!("{label}/ttft_p50_ms"), pct(&ttfts, 50.0)));
        rows.push((format!("{label}/ttft_p95_ms"), pct(&ttfts, 95.0)));
        rows.push((format!("{label}/ttft_p99_ms"), pct(&ttfts, 99.0)));
        rows.push((format!("{label}/itl_p50_ms"), pct(&gaps, 50.0)));
        rows.push((format!("{label}/itl_p95_ms"), pct(&gaps, 95.0)));
        rows.push((format!("{label}/itl_p99_ms"), pct(&gaps, 99.0)));
        rows.push((format!("{label}/tok_s"), tok_s));
        rows.push((format!("{label}/rejected"), rejected as f64));
    }

    let table = lines.join("\n");
    println!("{table}");
    let path = write_report("serving_load", &table, Some(kv_rows_json(&rows)))
        .expect("write serving_load report");
    println!("report: {}", path.display());
}
