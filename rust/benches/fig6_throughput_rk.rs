//! Regenerates paper Figure 6: attention throughput sweep, platform config A
//! (single thread — the RK3588S2 stand-in; see DESIGN.md §2).
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let lens = exp::default_seq_lens();
    let rows = exp::speed_sweep(&lens, exp::HEAD_DIM, 1);
    let table = exp::render_speed(&rows, "Figure 6 — throughput, cfg-A (1 thread)");
    table.print();
    let _ = write_report("fig6_throughput_rk", &table.render(), None);
}
