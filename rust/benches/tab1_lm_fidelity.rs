//! Regenerates paper Table 1 (substituted per DESIGN.md §2): end-to-end LM
//! fidelity — perplexity + top-1 agreement per pipeline on the tiny LM.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let w = exp::load_or_random_weights();
    let rows = exp::tab1_lm_fidelity(&w, 6, 160);
    let table = exp::render_lm_fidelity(&rows, "Table 1 — end-to-end LM fidelity");
    table.print();
    let _ = write_report("tab1_lm_fidelity", &table.render(), None);
}
