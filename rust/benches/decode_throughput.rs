//! Decode throughput over the stateful KV path, two modes:
//!
//! 1. **Single-sequence sweep** — tokens/sec for the headline pipelines at
//!    several resident context lengths, plus the per-token Quantize-stage
//!    time — which stays flat in context length for the stateful integer
//!    pipelines (the whole point: no per-token history re-quantization)
//!    while total step time grows with the two GEMMs.
//! 2. **Multi-sequence mode** — aggregate tok/s for B concurrently decoding
//!    sequences at a fixed context, sequential loop vs one grouped
//!    `decode_step_batch` per round. A 1-row decode GEMM cannot be split
//!    across worker threads, so the sequential loop is stuck at one core;
//!    the grouped kernels spread the pool across sequences, and the batch-8
//!    speedup is the headline number of the batched-decode work.
use intattention::harness::experiments as exp;
use intattention::harness::report::{kv_rows_json, write_report};
use intattention::util::threadpool::default_threads;

fn main() {
    let fast = std::env::var("INTATTN_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let ctxs: Vec<usize> = if fast {
        vec![64, 256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![256, 1024, 4096, 8192]
    } else {
        vec![128, 512, 1024, 2048]
    };
    let gen_tokens = if fast { 8 } else { 64 };
    let rows = exp::decode_sweep(&ctxs, exp::HEAD_DIM, gen_tokens, 1);
    let table = exp::render_decode(&rows);
    table.print();
    let _ = write_report(
        "decode_throughput",
        &table.render(),
        Some(kv_rows_json(&exp::decode_rows_json(&rows))),
    );

    // Multi-sequence mode: batched decode through the grouped kernels vs
    // the sequential loop at the same context length. The context must be
    // deep enough that batch-8 grouped launches clear the int8 work-grain
    // guard (8·ctx·d ≥ PAR_GRAIN_I8, i.e. ctx ≥ 1024 at d=128) — below
    // that the integer launches deliberately stay inline and only the
    // costlier-per-element FP16/FP32 rows show cross-sequence threading.
    let threads = default_threads().min(8);
    let (batch_ctx, batches, rounds) = if fast {
        (64, vec![1, 4], 4)
    } else {
        (2048, vec![1, 2, 4, 8], 16)
    };
    let brows = exp::batched_decode_sweep(batch_ctx, &batches, exp::HEAD_DIM, rounds, threads);
    let btable = exp::render_batched_decode(&brows);
    btable.print();
    let _ = write_report(
        "decode_throughput_batched",
        &btable.render(),
        Some(kv_rows_json(&exp::batched_decode_rows_json(&brows))),
    );
}
