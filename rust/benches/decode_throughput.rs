//! Decode throughput over the stateful KV path, plus the parallel-runtime
//! launch-overhead microbench. Modes:
//!
//! 1. **Launch overhead** — ns per parallel launch: spawn-per-launch
//!    (`std::thread::scope` via `scope_chunks_with`, what every grouped
//!    decode GEMM used to pay per call) vs persistent dispatch onto the
//!    parked [`ParallelPool`] workers. The ratio is the reason the pool's
//!    grain threshold could drop ~1.5 orders of magnitude below the old
//!    `PAR_GRAIN_*` constants; persistent dispatch is expected to be ≥10×
//!    cheaper on real hardware.
//! 2. **Single-sequence sweep** — tokens/sec for the headline pipelines at
//!    several resident context lengths, plus the per-token Quantize-stage
//!    time — which stays flat in context length for the stateful integer
//!    pipelines (no per-token history re-quantization). Each row also
//!    reports the paged-KV residency (pages, exact allocated bytes) and
//!    the append-path copy bytes the pre-paging contiguous layout would
//!    have paid to `Vec` growth over the same schedule (paged pays zero —
//!    appends fill the tail page in place).
//! 3. **Multi-sequence mode** — aggregate tok/s for B concurrently decoding
//!    sequences, sequential loop vs one grouped `decode_step_batch` per
//!    round, at a deep context *and* at a short context. The short-context
//!    rows are the persistent-runtime headline: below the old spawn-cost
//!    grain (8·ctx·d < 2^20) the previous design forced integer launches
//!    inline, so any batched speedup there is new.
//! 4. **Long-context sweep** — the paged-allocation headline: deep decode
//!    runs where the contiguous layout's realloc copy traffic grows with
//!    the resident length while the paged layout never re-copies history.
//!    Also reports the process-wide page-pool counters
//!    (allocated/recycled).
//! 5. **Shared-system-prompt sweep** — the prefix-sharing headline: N
//!    requests admitting the same prompt prefix, unshared (N quantize+store
//!    passes, N page sets) vs copy-on-write shared (1 pass, 1 prefix page
//!    set + per-request suffixes) — the `decode_prefix_shared` report.
//! 6. **Fused flash-decode sweep** — the fused-walk headline: tok/s for the
//!    fused-capable integer pipelines with `fused_decode` forced off vs on
//!    over identical inputs, deep contexts included (≥ 2048 outside fast
//!    mode, where the unfused path's L-length score row hurts most) — the
//!    `decode_fused` report, with the fused/unfused output cosine riding
//!    along as a fidelity witness.
//! 7. **Page-parallel fused decode + tiled prefill** — the span-split
//!    headline: a threads × context grid of sequential-fused
//!    (`decode_split(1)`) vs page-parallel (`decode_split(0)`) tok/s —
//!    batch-of-1 deep-context decode scaling with the pool — plus a tiled
//!    vs materialized prefill comparison with wall time and **peak heap
//!    bytes** per arm, measured by this binary's peak-tracking global
//!    allocator. Written as the `decode_parallel_fused` report.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use intattention::harness::experiments as exp;
use intattention::harness::report::{kv_rows_json, write_report};
use intattention::util::bench::black_box;
use intattention::util::threadpool::{default_threads, scope_chunks_with, ParallelPool};

struct PeakAlloc;

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` plus relaxed atomic live/peak
// watermarks — the allocator obligations (layout fidelity, no unwinding,
// no reentrant allocation) are exactly `System`'s, which the delegation
// preserves (same idiom as tests/decode_alloc.rs).
unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let sz = layout.size() as u64;
        let live = LIVE.fetch_add(sz, Ordering::Relaxed) + sz;
        PEAK.fetch_max(live, Ordering::Relaxed);
        // SAFETY: `layout` is forwarded unmodified from our caller, who
        // upholds `GlobalAlloc::alloc`'s contract (non-zero size).
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: `ptr`/`layout` come from our caller's matching `alloc`,
        // which delegated to `System`, so they denote a live System block.
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if new_size > layout.size() {
            let grow = (new_size - layout.size()) as u64;
            let live = LIVE.fetch_add(grow, Ordering::Relaxed) + grow;
            PEAK.fetch_max(live, Ordering::Relaxed);
        } else {
            LIVE.fetch_sub((layout.size() - new_size) as u64, Ordering::Relaxed);
        }
        // SAFETY: same delegation argument as `dealloc`, and `new_size`
        // is forwarded under the caller's `realloc` contract.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[global_allocator]
static ALLOC: PeakAlloc = PeakAlloc;

/// Peak heap bytes `f` adds on top of the live watermark at entry: the
/// peak is rebased to the current live count, `f` runs, and the high-water
/// delta comes back — so resident state built before the probe doesn't
/// drown the per-call signal. Worker threads allocate against the same
/// process-global counters, so pooled prefill arms are fully accounted.
fn peak_during(f: &mut dyn FnMut()) -> u64 {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    f();
    PEAK.load(Ordering::Relaxed).saturating_sub(base)
}

/// Mean ns/launch for `reps` `threads`-wide launches through each
/// dispatcher. Every launch runs `threads` single-item chunks whose body is
/// a barrier rendezvous: each of the `threads` participating OS threads
/// must claim exactly one chunk and meet the others, so both numbers
/// include the full cross-thread cost — worker wakeup latency for the
/// persistent pool, thread spawn for the scoped path. (A trivial body would
/// let the *calling* thread drain all chunks before any parked worker woke,
/// and the "dispatch" number would dishonestly omit the wakeups.)
fn launch_overhead(threads: usize, reps: usize) -> (f64, f64) {
    use std::sync::Barrier;
    // Grain 1 so the persistent path genuinely dispatches at this tiny n
    // (mirroring a small grouped decode launch).
    let pool = ParallelPool::with_grain(threads, 1);
    let barrier = Barrier::new(threads);
    // Warmup: fault in stacks, park workers.
    for _ in 0..reps / 10 + 1 {
        scope_chunks_with(threads, threads, |_s, _e| {
            barrier.wait();
        });
        pool.parallel_for(threads, usize::MAX, |_s, _e| {
            barrier.wait();
        });
    }
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        scope_chunks_with(threads, threads, |s, e| {
            barrier.wait();
            black_box(s + e);
        });
    }
    let spawn_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        pool.parallel_for(threads, usize::MAX, |s, e| {
            barrier.wait();
            black_box(s + e);
        });
    }
    let dispatch_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
    (spawn_ns, dispatch_ns)
}

fn main() {
    let fast = intattention::util::env::knobs().bench_fast;

    // -- Mode 1: launch overhead ----------------------------------------
    // Fixed 4-wide launches (oversubscription on small hosts only adds
    // scheduler noise to *both* paths) so numbers are comparable across
    // machines.
    let (reps, width) = if fast { (200, 4) } else { (2000, 4) };
    let (spawn_ns, dispatch_ns) = launch_overhead(width, reps);
    let ratio = spawn_ns / dispatch_ns.max(1e-9);
    println!(
        "launch overhead ({width}-wide, {reps} reps): spawn-per-launch {spawn_ns:.0} ns, \
         persistent dispatch {dispatch_ns:.0} ns, ratio {ratio:.1}x"
    );
    let _ = write_report(
        "launch_overhead",
        &format!(
            "spawn_per_launch_ns {spawn_ns:.0}\npersistent_dispatch_ns {dispatch_ns:.0}\nratio {ratio:.2}\n"
        ),
        Some(kv_rows_json(&[
            ("spawn_per_launch_ns".to_string(), spawn_ns),
            ("persistent_dispatch_ns".to_string(), dispatch_ns),
            ("ratio".to_string(), ratio),
        ])),
    );

    // -- Mode 2: single-sequence decode sweep ---------------------------
    let ctxs: Vec<usize> = if fast {
        vec![64, 256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![256, 1024, 4096, 8192]
    } else {
        vec![128, 512, 1024, 2048]
    };
    let gen_tokens = if fast { 8 } else { 64 };
    let rows = exp::decode_sweep(&ctxs, exp::HEAD_DIM, gen_tokens, 1);
    let table = exp::render_decode(&rows);
    table.print();
    let _ = write_report(
        "decode_throughput",
        &table.render(),
        Some(kv_rows_json(&exp::decode_rows_json(&rows))),
    );

    // -- Mode 3: multi-sequence batched decode --------------------------
    // Deep context (GEMM-bound) and short context (launch-overhead-bound:
    // the regime the old per-launch thread spawns kept single-threaded).
    let threads = default_threads().min(8);
    let (deep_ctx, short_ctx, batches, rounds) = if fast {
        (64, 32, vec![1, 4], 4)
    } else {
        (2048, 128, vec![1, 2, 4, 8], 16)
    };
    for (name, ctx) in [("decode_throughput_batched", deep_ctx), ("decode_throughput_batched_short", short_ctx)] {
        let brows = exp::batched_decode_sweep(ctx, &batches, exp::HEAD_DIM, rounds, threads);
        let btable = exp::render_batched_decode(&brows);
        btable.print();
        let _ = write_report(name, &btable.render(), Some(kv_rows_json(&exp::batched_decode_rows_json(&brows))));
    }

    // -- Mode 4: long-context paged-KV sweep ----------------------------
    // Deep resident contexts with a long decode tail: the regime where the
    // pre-paging contiguous layout's realloc copies grow with the resident
    // length (reported per row as "append copy B (contig→paged)") while
    // paged appends never touch history.
    let long_ctxs: Vec<usize> = if fast {
        vec![96]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![4096, 8192, 16384]
    } else {
        vec![2048, 4096]
    };
    let long_gen = if fast { 16 } else { 256 };
    // Snapshot the process-wide pool counters around the sweep so the
    // report describes *this* mode's page traffic, not the whole bench run.
    let pool_before = intattention::attention::page_pool_stats();
    let lrows = exp::decode_sweep(&long_ctxs, exp::HEAD_DIM, long_gen, 1);
    let pool_after = intattention::attention::page_pool_stats();
    let (pages_alloc, pages_recycled) = (
        pool_after.allocated - pool_before.allocated,
        pool_after.recycled - pool_before.recycled,
    );
    let ltable = exp::render_decode(&lrows);
    ltable.print();
    println!("page pool (this sweep): {pages_alloc} allocated, {pages_recycled} recycled");
    let mut ljson = exp::decode_rows_json(&lrows);
    ljson.push(("kv_pages_allocated".to_string(), pages_alloc as f64));
    ljson.push(("kv_pages_recycled".to_string(), pages_recycled as f64));
    let _ = write_report("decode_longctx_paged", &ltable.render(), Some(kv_rows_json(&ljson)));

    // -- Mode 5: shared-system-prompt prefix sharing ---------------------
    // N requests admit the same system prompt: the unshared arm quantizes
    // and stores the prefix N times, the shared arm once (adopters take the
    // pages by copy-on-write reference and pay only their suffixes). The
    // report starts the BENCH_* perf trajectory for admission-path sharing:
    // prefix quantization passes, exact page traffic, and wall time.
    let (n_list, prefix_rows, suffix_rows) = if fast {
        (vec![4usize], 64, 8)
    } else {
        (vec![4usize, 16], 512, 32)
    };
    let prows = exp::prefix_share_sweep(&n_list, prefix_rows, suffix_rows, exp::HEAD_DIM);
    let ptable = exp::render_prefix_share(&prows);
    ptable.print();
    let _ = write_report(
        "decode_prefix_shared",
        &ptable.render(),
        Some(kv_rows_json(&exp::prefix_share_rows_json(&prows))),
    );

    // -- Mode 6: fused flash-decode sweep --------------------------------
    // Deep contexts are the acceptance regime: at L ≥ 2048 the fused walk
    // (one K̂/V̂ page pass, no L-length row) must hold tok/s at or above the
    // unfused three-pass decode.
    let fctxs: Vec<usize> = if fast {
        vec![64, 256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![512, 2048, 4096, 8192]
    } else {
        vec![512, 2048, 4096]
    };
    let fgen = if fast { 8 } else { 64 };
    let frows = exp::fused_decode_sweep(&fctxs, exp::HEAD_DIM, fgen, threads);
    let ftable = exp::render_fused_decode(&frows);
    ftable.print();
    let _ = write_report(
        "decode_fused",
        &ftable.render(),
        Some(kv_rows_json(&exp::fused_decode_rows_json(&frows))),
    );

    // -- Mode 7: page-parallel fused decode + tiled prefill --------------
    // (a) Threads × context grid: both arms run the fused walk, only the
    // span-split policy differs — sequential one-span vs the page list cut
    // across the pool with the exact integer merge. The acceptance regime
    // is batch-of-1 deep context, where the sequential walk leaves every
    // worker but one idle.
    let thread_list: Vec<usize> = if fast {
        vec![1, 2]
    } else {
        let t = default_threads().min(8);
        let mut l = vec![1, 2, 4, 8];
        l.retain(|&x| x <= t.max(2));
        l
    };
    let pctxs: Vec<usize> = if fast {
        vec![256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![2048, 4096, 8192]
    } else {
        vec![2048, 4096]
    };
    let pgen = if fast { 8 } else { 64 };
    let prows2 = exp::parallel_fused_sweep(&pctxs, exp::HEAD_DIM, pgen, &thread_list);
    let ptable2 = exp::render_parallel_fused(&prows2);
    ptable2.print();

    // (b) Tiled vs materialized prefill: wall time per full-context block
    // plus each arm's peak heap bytes from this binary's peak-tracking
    // allocator — the materialized arm's m×L i32 score block dominates its
    // peak, the tiled arm's working set stays O(tile).
    let tctxs: Vec<usize> = if fast {
        vec![256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![1024, 4096, 8192]
    } else {
        vec![1024, 4096]
    };
    let trows = exp::tiled_prefill_sweep(&tctxs, exp::HEAD_DIM, threads, &mut peak_during);
    let ttable = exp::render_tiled_prefill(&trows);
    ttable.print();

    let mut pjson = exp::parallel_fused_rows_json(&prows2);
    pjson.extend(exp::tiled_prefill_rows_json(&trows));
    let _ = write_report(
        "decode_parallel_fused",
        &format!("{}\n{}", ptable2.render(), ttable.render()),
        Some(kv_rows_json(&pjson)),
    );
}
