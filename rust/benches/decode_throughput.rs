//! Decode throughput over the stateful KV path: tokens/sec for the headline
//! pipelines at several resident context lengths, plus the per-token
//! Quantize-stage time — which stays flat in context length for the
//! stateful integer pipelines (the whole point: no per-token history
//! re-quantization) while total step time grows with the two GEMMs.
use intattention::harness::experiments as exp;
use intattention::harness::report::{kv_rows_json, write_report};

fn main() {
    let fast = std::env::var("INTATTN_BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let ctxs: Vec<usize> = if fast {
        vec![64, 256]
    } else if std::env::var("INTATTN_FULL").map(|v| v == "1").unwrap_or(false) {
        vec![256, 1024, 4096, 8192]
    } else {
        vec![128, 512, 1024, 2048]
    };
    let gen_tokens = if fast { 8 } else { 64 };
    let rows = exp::decode_sweep(&ctxs, exp::HEAD_DIM, gen_tokens, 1);
    let table = exp::render_decode(&rows);
    table.print();
    let _ = write_report(
        "decode_throughput",
        &table.render(),
        Some(kv_rows_json(&exp::decode_rows_json(&rows))),
    );
}
