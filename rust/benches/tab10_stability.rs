//! Regenerates paper Table 10: token-loss stress test at long context —
//! max token loss, loss std-dev, NaN/Inf events (FP16 vs IndexSoftmax).
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let w = exp::load_or_random_weights();
    let rows = exp::tab10_stability(&w, 256, 4);
    let table = exp::render_tab10(&rows);
    table.print();
    let _ = write_report("tab10_stability", &table.render(), None);
}
