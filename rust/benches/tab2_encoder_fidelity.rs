//! Regenerates paper Table 2 (substituted): encoder-mode (vision-like,
//! bidirectional) output fidelity per pipeline vs the FP32 reference.
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;

fn main() {
    let rows = exp::tab2_encoder_fidelity(192, 64, 4);
    let table = exp::render_tab2(&rows);
    table.print();
    let _ = write_report("tab2_encoder_fidelity", &table.render(), None);
}
