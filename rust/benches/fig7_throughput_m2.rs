//! Regenerates paper Figure 7: attention throughput sweep, platform config B
//! (all host threads — the Apple M2 stand-in; see DESIGN.md §2).
use intattention::harness::experiments as exp;
use intattention::harness::report::write_report;
use intattention::util::threadpool::default_threads;

fn main() {
    let lens = exp::default_seq_lens();
    let rows = exp::speed_sweep(&lens, exp::HEAD_DIM, default_threads());
    let table = exp::render_speed(&rows, "Figure 7 — throughput, cfg-B (all threads)");
    table.print();
    let _ = write_report("fig7_throughput_m2", &table.render(), None);
}
