//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates-registry access, so this shim provides the
//! slice of anyhow's surface the project uses: [`Error`], [`Result`], the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Semantics follow upstream where it matters:
//!
//! * `{}` displays the outermost (most recent) context message;
//! * `{:#}` displays the whole chain, outermost first, `": "`-separated;
//! * `Error` deliberately does **not** implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` conversion (what makes `?` work on
//!   `io::Error` etc.) cannot conflict with the reflexive `From<Error>`.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a cause plus a stack of context messages.
pub struct Error {
    /// `chain[0]` is the root cause; later entries are contexts, innermost
    /// first (so the outermost context is `chain.last()`).
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.push(ctx.to_string());
        self
    }

    /// The error chain, outermost context first, root cause last.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }

    /// The root cause message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost first.
            let mut first = true;
            for part in self.chain.iter().rev() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(part)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(self.chain.last().expect("non-empty chain"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Upstream prints the outer message then a "Caused by" list.
        write!(f, "{}", self.chain.last().expect("non-empty chain"))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for part in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {part}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Fold `source()` links into the chain so `{:#}` shows them.
        let mut parts = Vec::new();
        parts.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            parts.push(s.to_string());
            src = s.source();
        }
        parts.reverse(); // chain[0] must be the deepest cause
        Error { chain: parts }
    }
}

/// Extension trait adding `.context()` / `.with_context()` to `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading weights").context("loading model");
        assert_eq!(format!("{e}"), "loading model");
        assert_eq!(format!("{e:#}"), "loading model: reading weights: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e}").contains("missing file"));
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn macros_compose() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(4).unwrap(), 8);
        assert!(format!("{}", f(-1).unwrap_err()).contains("must be positive"));
        assert!(format!("{}", f(200).unwrap_err()).contains("too big"));
        let e = anyhow!("standalone {}", 42);
        assert_eq!(format!("{e}"), "standalone 42");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = io_err().into();
        let e = e.context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("missing file"));
    }
}
